#!/usr/bin/env python3
"""Unit tests for the CI bench regression gate (stdlib unittest).

Doctored BENCH_hotpath.json payloads prove the gate actually asserts:
a healthy run passes, a sub-5x table speedup fails, a ceiling breach
fails, and a silently missing row fails instead of skipping. Doctored
BENCH_slo.json payloads do the same for the --slo mode: tail-latency
ceilings, goodput/attainment floors, required scenarios, and cross-worker
digest equality all bite.

Run:  python3 tools/test_bench_gate.py
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_gate


def healthy_rows():
    rows = {
        bench_gate.TABLE_REBUILD: 0.500,
        bench_gate.TABLE_INCR: 0.050,  # 10x
        bench_gate.MASK_REBUILD: 3.000,
        bench_gate.MASK_INCR: 1.200,  # 2.5x
        "decode-step metadata cycle (paged, incremental)": 2.0,
        "paged post_append scan (32 blocks)": 1.0,
        "inverse_key_norm global scan (512 tokens)": 20.0,
        "attn_feedback_step (512-pos mass + guided decision)": 25.0,
        "autotune_pick (snapshot + choose + record)": 1.0,
        "JSON request parse": 3.0,
        "argmax (4096 logits)": 4.0,
        "prefix_lookup chain+probe (4 blocks of 16)": 5.0,
        "cow_copy cycle (hit 4 blocks + make_private)": 40.0,
        "cancel_request (submit+prefill+cancel)": 60.0,
        "fault_passthrough decode step (no plan)": 30.0,
        "worker_handoff (steal_tail + inject)": 0.5,
        "cross_worker_preempt (preempt_min + restore round)": 80.0,
        "alloc_batch_16 (alloc_many, one lock)": 1.5,
        "release_batch_16 (release_many, one lock)": 1.2,
        "arena_contended_alloc (4 threads, cached)": 2.0,
        bench_gate.ENGINE_1W: 12.0,
        bench_gate.ENGINE_4W: 4.0,  # 3.0x scaling
        bench_gate.CORES: 8,
    }
    return rows


class CheckTests(unittest.TestCase):
    def run_check(self, rows, **kw):
        table = kw.pop("min_table_speedup", 5.0)
        mask = kw.pop("min_mask_speedup", 1.2)
        scaling = kw.pop("min_engine_scaling", 2.5)
        assert not kw
        return bench_gate.check(rows, table, mask, scaling)

    def test_healthy_run_passes(self):
        failures, report = self.run_check(healthy_rows())
        self.assertEqual(failures, [])
        self.assertTrue(any("10.0x" in line for line in report))

    def test_table_speedup_below_bar_fails(self):
        rows = healthy_rows()
        rows[bench_gate.TABLE_INCR] = rows[bench_gate.TABLE_REBUILD] / 4.0  # 4x < 5x
        failures, _ = self.run_check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("speedup regression", failures[0])
        self.assertIn("block_table", failures[0])

    def test_mask_slower_than_rebuild_fails(self):
        rows = healthy_rows()
        rows[bench_gate.MASK_INCR] = rows[bench_gate.MASK_REBUILD] * 1.1
        failures, _ = self.run_check(rows)
        self.assertTrue(any("valid_mask" in f for f in failures))

    def test_absolute_ceiling_breach_fails(self):
        rows = healthy_rows()
        rows["argmax (4096 logits)"] = 9999.0
        failures, _ = self.run_check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("absolute regression", failures[0])
        self.assertIn("argmax", failures[0])

    def test_cancel_request_ceiling_and_presence_are_gated(self):
        row = "cancel_request (submit+prefill+cancel)"
        rows = healthy_rows()
        rows[row] = 9999.0
        failures, _ = self.run_check(rows)
        self.assertTrue(any("cancel_request" in f and "absolute" in f for f in failures))
        rows = healthy_rows()
        del rows[row]
        failures, _ = self.run_check(rows)
        self.assertTrue(any("missing bench row" in f and "cancel_request" in f for f in failures))

    def test_fault_passthrough_ceiling_and_presence_are_gated(self):
        row = "fault_passthrough decode step (no plan)"
        rows = healthy_rows()
        rows[row] = 9999.0
        failures, _ = self.run_check(rows)
        self.assertTrue(
            any("fault_passthrough" in f and "absolute" in f for f in failures)
        )
        rows = healthy_rows()
        del rows[row]
        failures, _ = self.run_check(rows)
        self.assertTrue(
            any("missing bench row" in f and "fault_passthrough" in f for f in failures)
        )

    def test_worker_handoff_ceiling_and_presence_are_gated(self):
        row = "worker_handoff (steal_tail + inject)"
        rows = healthy_rows()
        rows[row] = 9999.0
        failures, _ = self.run_check(rows)
        self.assertTrue(any("worker_handoff" in f and "absolute" in f for f in failures))
        rows = healthy_rows()
        del rows[row]
        failures, _ = self.run_check(rows)
        self.assertTrue(
            any("missing bench row" in f and "worker_handoff" in f for f in failures)
        )

    def test_cross_worker_preempt_ceiling_is_gated(self):
        row = "cross_worker_preempt (preempt_min + restore round)"
        rows = healthy_rows()
        rows[row] = 99999.0
        failures, _ = self.run_check(rows)
        self.assertTrue(
            any("cross_worker_preempt" in f and "absolute" in f for f in failures)
        )

    def test_arena_batch_rows_ceiling_and_presence_are_gated(self):
        for row in (
            "alloc_batch_16 (alloc_many, one lock)",
            "release_batch_16 (release_many, one lock)",
            "arena_contended_alloc (4 threads, cached)",
        ):
            rows = healthy_rows()
            rows[row] = 99999.0
            failures, _ = self.run_check(rows)
            self.assertEqual(len(failures), 1, f"doctoring {row!r} must fail exactly once")
            self.assertIn("absolute regression", failures[0])
            self.assertIn(row, failures[0])
            rows = healthy_rows()
            del rows[row]
            failures, _ = self.run_check(rows)
            self.assertTrue(
                any("missing bench row" in f and row in f for f in failures),
                f"deleting {row!r} must fail the gate",
            )

    def test_attention_and_autotune_rows_ceiling_and_presence_are_gated(self):
        for row in (
            "attn_feedback_step (512-pos mass + guided decision)",
            "autotune_pick (snapshot + choose + record)",
        ):
            rows = healthy_rows()
            rows[row] = 99999.0
            failures, _ = self.run_check(rows)
            self.assertEqual(len(failures), 1, f"doctoring {row!r} must fail exactly once")
            self.assertIn("absolute regression", failures[0])
            self.assertIn(row, failures[0])
            rows = healthy_rows()
            del rows[row]
            failures, _ = self.run_check(rows)
            self.assertTrue(
                any("missing bench row" in f and row in f for f in failures),
                f"deleting {row!r} must fail the gate",
            )

    def test_engine_scaling_below_bar_fails(self):
        rows = healthy_rows()
        rows[bench_gate.ENGINE_4W] = rows[bench_gate.ENGINE_1W] / 2.0  # 2.0x < 2.5x
        failures, _ = self.run_check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("scaling regression", failures[0])

    def test_engine_scaling_skipped_below_four_cores(self):
        rows = healthy_rows()
        rows[bench_gate.ENGINE_4W] = rows[bench_gate.ENGINE_1W]  # no scaling at all
        rows[bench_gate.CORES] = 2
        failures, report = self.run_check(rows)
        self.assertEqual(failures, [])
        self.assertTrue(any("ratio check skipped" in line for line in report))

    def test_engine_scaling_threshold_flag(self):
        rows = healthy_rows()
        rows[bench_gate.ENGINE_4W] = rows[bench_gate.ENGINE_1W] / 2.0
        failures, _ = self.run_check(rows, min_engine_scaling=1.5)
        self.assertEqual(failures, [])

    def test_missing_engine_rows_fail(self):
        for row in (bench_gate.ENGINE_1W, bench_gate.ENGINE_4W, bench_gate.CORES):
            rows = healthy_rows()
            del rows[row]
            failures, _ = self.run_check(rows)
            self.assertTrue(
                any("missing bench row" in f and row in f for f in failures),
                f"deleting {row!r} must fail the gate",
            )

    def test_missing_row_fails_instead_of_skipping(self):
        rows = healthy_rows()
        del rows[bench_gate.TABLE_INCR]
        failures, _ = self.run_check(rows)
        self.assertTrue(any("missing bench row" in f for f in failures))

    def test_non_numeric_row_fails(self):
        rows = healthy_rows()
        rows[bench_gate.MASK_INCR] = "fast"
        failures, _ = self.run_check(rows)
        self.assertTrue(any("non-numeric" in f for f in failures))


def healthy_slo_row(scenario, workers, digest="00aa11bb22cc33dd", **over):
    row = {
        "scenario": scenario,
        "workers": workers,
        "requests": 48,
        "completed": 48,
        "digest": digest,
        "policy": "paged",
        "policy_counts": {"paged": 48},
        "elapsed_s": 1.2,
        "ttft_p50_ms": 4.0,
        "ttft_p99_ms": 35.0,
        "tpot_p50_ms": 0.8,
        "tpot_p99_ms": 2.5,
        "slo_attainment": 1.0,
        "goodput_tok_s": 2500.0,
        "preemptions": 2,
        "steals": 3,
        "cross_preempts": 2,
        "lock_acquisitions": 400,
        "contended_acquisitions": 12,
        "cache_refills": 40,
        "cache_drains": 1,
    }
    row.update(over)
    return row


def healthy_slo():
    return {
        "schema": "slo-v1",
        "seed": 42,
        "rows": [
            healthy_slo_row("bursty-chat", 1, "aa"),
            healthy_slo_row("bursty-chat", 4, "aa"),
            healthy_slo_row("longbench-replay", 1, "bb"),
            healthy_slo_row("longbench-replay", 4, "bb"),
            # at 1 worker saturate-steal runs its marathons back to back:
            # zero contention activity is the HEALTHY single-worker shape
            healthy_slo_row(
                "saturate-steal",
                1,
                "cc",
                requests=28,
                completed=28,
                steals=0,
                cross_preempts=0,
                preemptions=0,
            ),
            healthy_slo_row("saturate-steal", 4, "cc", requests=28, completed=28),
        ],
    }


class SloCheckTests(unittest.TestCase):
    def test_healthy_slo_run_passes(self):
        failures, report = bench_gate.check_slo(healthy_slo())
        self.assertEqual(failures, [])
        self.assertTrue(any("ttft p99" in line for line in report))

    def test_ttft_ceiling_breach_fails(self):
        data = healthy_slo()
        data["rows"][0]["ttft_p99_ms"] = 99999.0
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("tail regression", failures[0])
        self.assertIn("ttft p99", failures[0])
        self.assertIn("bursty-chat", failures[0])

    def test_tpot_ceiling_breach_fails(self):
        data = healthy_slo()
        data["rows"][2]["tpot_p99_ms"] = 99999.0
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("tail regression", failures[0])
        self.assertIn("tpot p99", failures[0])
        self.assertIn("longbench-replay", failures[0])

    def test_goodput_floor_violation_fails(self):
        data = healthy_slo()
        data["rows"][0]["goodput_tok_s"] = 0.1
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("goodput regression", failures[0])

    def test_attainment_floor_violation_fails(self):
        data = healthy_slo()
        data["rows"][1]["slo_attainment"] = 0.05
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("attainment regression", failures[0])

    def test_missing_scenario_fails_instead_of_skipping(self):
        data = healthy_slo()
        data["rows"] = [r for r in data["rows"] if r["scenario"] != "longbench-replay"]
        failures, _ = bench_gate.check_slo(data)
        self.assertTrue(
            any("missing slo scenario" in f and "longbench-replay" in f for f in failures)
        )

    def test_digest_divergence_across_workers_fails(self):
        data = healthy_slo()
        data["rows"][1]["digest"] = "deadbeefdeadbeef"
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("determinism violation", failures[0])
        self.assertIn("bursty-chat", failures[0])

    def test_missing_digest_fails(self):
        data = healthy_slo()
        del data["rows"][3]["digest"]
        failures, _ = bench_gate.check_slo(data)
        self.assertTrue(any("missing output digest" in f for f in failures))

    def test_incomplete_run_fails(self):
        data = healthy_slo()
        data["rows"][0]["completed"] = 3
        failures, _ = bench_gate.check_slo(data)
        self.assertTrue(any("3 of 48 requests completed" in f for f in failures))

    def test_non_numeric_metric_fails(self):
        data = healthy_slo()
        data["rows"][0]["ttft_p99_ms"] = "fast"
        failures, _ = bench_gate.check_slo(data)
        self.assertTrue(any("non-numeric field" in f for f in failures))

    def test_missing_contention_counter_fails(self):
        for field in (
            "lock_acquisitions",
            "contended_acquisitions",
            "cache_refills",
            "cache_drains",
        ):
            data = healthy_slo()
            del data["rows"][0][field]
            failures, _ = bench_gate.check_slo(data)
            self.assertEqual(len(failures), 1, f"dropping {field!r} must fail exactly once")
            self.assertIn("non-numeric field", failures[0])
            self.assertIn(field, failures[0])

    def test_missing_policy_field_fails(self):
        for doctored in (None, "", 42):
            data = healthy_slo()
            if doctored is None:
                del data["rows"][0]["policy"]
            else:
                data["rows"][0]["policy"] = doctored
            failures, _ = bench_gate.check_slo(data)
            self.assertEqual(len(failures), 1, f"policy={doctored!r} must fail once")
            self.assertIn("missing 'policy' field", failures[0])
            self.assertIn("bursty-chat", failures[0])

    def test_auto_row_without_policy_counts_fails(self):
        for counts in (None, {}, "paged=48"):
            data = healthy_slo()
            data["rows"][0]["policy"] = "auto"
            if counts is None:
                del data["rows"][0]["policy_counts"]
            else:
                data["rows"][0]["policy_counts"] = counts
            failures, _ = bench_gate.check_slo(data)
            self.assertEqual(
                len(failures), 1, f"policy_counts={counts!r} must fail once"
            )
            self.assertIn("no 'policy_counts' breakdown", failures[0])

    def test_auto_sentinel_leaking_into_policy_counts_fails(self):
        data = healthy_slo()
        data["rows"][0]["policy"] = "auto"
        data["rows"][0]["policy_counts"] = {"paged": 40, "auto": 8}
        failures, _ = bench_gate.check_slo(data)
        self.assertEqual(len(failures), 1)
        self.assertIn("'auto' leaked into policy_counts", failures[0])

    def test_auto_row_with_resolved_counts_passes_and_reports(self):
        data = healthy_slo()
        for row in data["rows"]:
            if row["scenario"] == "bursty-chat":
                row["policy"] = "auto"
                row["policy_counts"] = {"paged": 40, "self_attn": 8}
        failures, report = bench_gate.check_slo(data)
        self.assertEqual(failures, [])
        self.assertTrue(
            any("auto resolved paged=40 self_attn=8" in line for line in report)
        )

    def saturate_row(self, data, workers):
        return next(
            r
            for r in data["rows"]
            if r["scenario"] == "saturate-steal" and r["workers"] == workers
        )

    def test_contention_floors_bite_multi_worker_rows(self):
        for field in ("steals", "cross_preempts", "preemptions"):
            data = healthy_slo()
            self.saturate_row(data, 4)[field] = 0
            failures, _ = bench_gate.check_slo(data)
            self.assertEqual(len(failures), 1, f"zeroing {field!r} must fail exactly once")
            self.assertIn("contention floor", failures[0])
            self.assertIn(field, failures[0])

    def test_contention_floors_waived_on_single_worker_rows(self):
        # the healthy fixture's 1-worker saturate-steal row already has
        # zero steals/cross-preempts/preemptions and must pass
        failures, report = bench_gate.check_slo(healthy_slo())
        self.assertEqual(failures, [])
        self.assertTrue(any("floor waived" in line for line in report))

    def test_missing_saturate_steal_scenario_fails(self):
        data = healthy_slo()
        data["rows"] = [r for r in data["rows"] if r["scenario"] != "saturate-steal"]
        failures, _ = bench_gate.check_slo(data)
        self.assertTrue(
            any("missing slo scenario" in f and "saturate-steal" in f for f in failures)
        )

    def test_malformed_payload_fails(self):
        failures, _ = bench_gate.check_slo([1, 2, 3])
        self.assertTrue(any("'rows' list" in f for f in failures))
        failures, _ = bench_gate.check_slo({"rows": "nope"})
        self.assertTrue(any("'rows' list" in f for f in failures))
        failures, _ = bench_gate.check_slo({"rows": [42]})
        self.assertTrue(any("naming a 'scenario'" in f for f in failures))


class MainTests(unittest.TestCase):
    def write_json(self, payload):
        f = tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, encoding="utf-8"
        )
        self.addCleanup(os.unlink, f.name)
        with f:
            if isinstance(payload, str):
                f.write(payload)
            else:
                json.dump(payload, f)
        return f.name

    def test_main_pass_and_fail_exit_codes(self):
        good = self.write_json(healthy_rows())
        self.assertEqual(bench_gate.main([good]), 0)
        doctored = healthy_rows()
        doctored[bench_gate.TABLE_INCR] = doctored[bench_gate.TABLE_REBUILD]  # 1x
        bad = self.write_json(doctored)
        self.assertEqual(bench_gate.main([bad]), 1)

    def test_main_threshold_flags(self):
        rows = healthy_rows()
        rows[bench_gate.TABLE_INCR] = rows[bench_gate.TABLE_REBUILD] / 4.0
        path = self.write_json(rows)
        self.assertEqual(bench_gate.main([path]), 1)
        self.assertEqual(bench_gate.main(["--min-table-speedup", "3", path]), 0)

    def test_main_rejects_garbage_input(self):
        self.assertEqual(bench_gate.main([self.write_json("not json")]), 1)
        self.assertEqual(bench_gate.main([self.write_json([1, 2])]), 1)
        self.assertEqual(bench_gate.main(["/nonexistent/bench.json"]), 1)

    def test_main_slo_mode_pass_and_fail(self):
        good = self.write_json(healthy_slo())
        self.assertEqual(bench_gate.main(["--slo", good]), 0)
        doctored = healthy_slo()
        doctored["rows"][0]["ttft_p99_ms"] = 99999.0
        self.assertEqual(bench_gate.main(["--slo", self.write_json(doctored)]), 1)
        # the same healthy slo payload is NOT a valid us/op bench
        self.assertEqual(bench_gate.main([good]), 1)


if __name__ == "__main__":
    unittest.main(verbosity=2)
