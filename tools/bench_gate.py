#!/usr/bin/env python3
"""CI regression gate over the hot-path microbench output.

Reads the ``BENCH_hotpath.json`` emitted by ``cargo bench --bench
micro_hotpath`` (a flat ``{op name: microseconds/op}`` object) and FAILS
(exit 1) when:

  * the incremental block-table serialization is not at least
    ``--min-table-speedup`` (default 5x) faster than the legacy
    from-scratch rebuild — the bar PR 1 introduced and ROADMAP records;
  * the incremental validity-mask serialization is not at least
    ``--min-mask-speedup`` (default 1.2x) faster than its rebuild. Both
    mask rows end in the same O(NB*B) consume pass (which dominates), so
    the achievable ratio is structurally far below the table pair's; the
    gate asserts the incremental path never regresses BELOW the rebuild
    rather than an unreachable 5x;
  * any gated op exceeds its absolute ceiling in ``CEILINGS_US`` —
    generous catastrophic-regression bounds (10-100x expected values),
    sized for noisy shared CI runners, not laptops;
  * the multi-worker engine's 4-worker aggregate decode throughput is
    not at least ``--min-engine-scaling`` (default 2.5x) the 1-worker
    number. The bench records the machine's core count alongside the two
    throughput rows; on runners with fewer than 4 cores the RATIO check
    is skipped (the parallelism physically is not there) while the
    rows' presence and absolute ceilings still apply;
  * any row the gate needs is missing (a silently renamed bench row must
    not turn the gate into a no-op).

Stdlib only — runs on a bare CI python with no installs.

Usage:
    python3 tools/bench_gate.py rust/BENCH_hotpath.json
    python3 tools/bench_gate.py --min-table-speedup 5 bench.json
"""

import argparse
import json
import sys

TABLE_REBUILD = "block_table rebuild+consume (64 blocks)"
TABLE_INCR = "block_table incremental+consume (64 blocks)"
MASK_REBUILD = "valid_mask rebuild+consume (1024 slots)"
MASK_INCR = "valid_mask incremental+consume (1024 slots)"
ENGINE_1W = "engine decode throughput, 1 worker (us/token)"
ENGINE_4W = "engine decode throughput, 4 workers (us/token)"
CORES = "cpu cores available"

# Absolute per-op ceilings in microseconds. Deliberately loose: they exist
# to catch an accidental O(n) -> O(n^2) (or a stray allocation storm), not
# to police single-digit-percent noise.
CEILINGS_US = {
    TABLE_INCR: 5.0,
    MASK_INCR: 50.0,
    TABLE_REBUILD: 100.0,
    MASK_REBUILD: 500.0,
    "decode-step metadata cycle (paged, incremental)": 250.0,
    "paged post_append scan (32 blocks)": 250.0,
    "inverse_key_norm global scan (512 tokens)": 2000.0,
    "JSON request parse": 500.0,
    "argmax (4096 logits)": 250.0,
    # prefix cache: hash a 4-block chain + probe the index (admission
    # cost), and the full hit-4-pages + one copy-on-write cycle. Both are
    # per-PREFILL costs, not per-token, so the ceilings are generous.
    "prefix_lookup chain+probe (4 blocks of 16)": 250.0,
    "cow_copy cycle (hit 4 blocks + make_private)": 2000.0,
    # session API teardown: a full submit + prefill + one decode round +
    # synchronous cancel (blocks back in the arena before it returns).
    # Per-request cost dominated by the sim prefill, hence the slack.
    "cancel_request (submit+prefill+cancel)": 2000.0,
    # one steady-state scheduler decode round through the FaultyBackend
    # wrapper with NO plan — the passthrough path must stay ~free, since
    # it sits on the hot path whenever fault injection is compiled in.
    "fault_passthrough decode step (no plan)": 500.0,
    # multi-worker engine: a work-stealing handoff is pure queue surgery
    # (steal_tail + inject, no block traffic) and must stay that cheap...
    "worker_handoff (steal_tail + inject)": 250.0,
    # ... while a cross-worker preemption cycle snapshots the victim into
    # the shared swap pool and restores it a round later — a per-PRESSURE
    # cost, not per-token, hence the slack.
    "cross_worker_preempt (preempt_min + restore round)": 5000.0,
    # aggregate sim decode through the engine; loose per-token bounds so
    # an accidental serialization (one giant lock) still trips them.
    ENGINE_1W: 2000.0,
    ENGINE_4W: 2000.0,
}


def check(rows, min_table_speedup, min_mask_speedup, min_engine_scaling=2.5):
    """Return (failures, report_lines) for a {op: us/op} mapping."""
    failures = []
    report = []
    bad_rows = set()  # report each missing/bad row once, not per consumer

    def lookup(name):
        v = rows.get(name)
        if v is None:
            if name not in bad_rows:
                bad_rows.add(name)
                failures.append(f"missing bench row: {name!r}")
        elif not isinstance(v, (int, float)) or v != v or v < 0:
            if name not in bad_rows:
                bad_rows.add(name)
                failures.append(f"non-numeric bench row: {name!r} = {v!r}")
            return None
        return v

    pairs = [
        ("block_table", TABLE_REBUILD, TABLE_INCR, min_table_speedup),
        ("valid_mask", MASK_REBUILD, MASK_INCR, min_mask_speedup),
    ]
    for label, rebuild_row, incr_row, floor in pairs:
        rebuild, incr = lookup(rebuild_row), lookup(incr_row)
        if rebuild is None or incr is None:
            continue
        speedup = rebuild / max(incr, 1e-9)
        line = f"{label}: rebuild {rebuild:.3f} us -> incremental {incr:.3f} us ({speedup:.1f}x, need >= {floor:.1f}x)"
        report.append(line)
        if speedup < floor:
            failures.append(f"speedup regression: {line}")

    for name, ceiling in sorted(CEILINGS_US.items()):
        v = lookup(name)
        if v is None:
            continue
        report.append(f"ceiling: {name}: {v:.3f} us (<= {ceiling:.1f} us)")
        if v > ceiling:
            failures.append(
                f"absolute regression: {name}: {v:.3f} us exceeds the {ceiling:.1f} us ceiling"
            )

    # multi-worker scaling: 4 workers over one shared arena must actually
    # saturate the cores. Only meaningful where 4 cores exist — the bench
    # reports the machine's parallelism so a 2-core runner skips the
    # ratio (the rows themselves are still required above/below).
    us1, us4, cores = lookup(ENGINE_1W), lookup(ENGINE_4W), lookup(CORES)
    if us1 is not None and us4 is not None and cores is not None:
        scaling = us1 / max(us4, 1e-9)
        if cores >= 4:
            line = (
                f"engine scaling: {us1:.3f} us/token (1w) -> {us4:.3f} us/token (4w) "
                f"({scaling:.2f}x, need >= {min_engine_scaling:.1f}x on {cores:.0f} cores)"
            )
            report.append(line)
            if scaling < min_engine_scaling:
                failures.append(f"scaling regression: {line}")
        else:
            report.append(
                f"engine scaling: {scaling:.2f}x observed, ratio check skipped "
                f"({cores:.0f} core(s) < 4)"
            )

    return failures, report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("json_path", help="path to BENCH_hotpath.json")
    ap.add_argument("--min-table-speedup", type=float, default=5.0)
    ap.add_argument("--min-mask-speedup", type=float, default=1.2)
    ap.add_argument("--min-engine-scaling", type=float, default=2.5)
    args = ap.parse_args(argv)

    try:
        with open(args.json_path, "r", encoding="utf-8") as f:
            rows = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot read {args.json_path}: {e}", file=sys.stderr)
        return 1
    if not isinstance(rows, dict):
        print("bench gate: bench JSON must be an object of op -> us/op", file=sys.stderr)
        return 1

    failures, report = check(
        rows, args.min_table_speedup, args.min_mask_speedup, args.min_engine_scaling
    )
    for line in report:
        print(f"  {line}")
    if failures:
        print(f"\nbench gate FAILED ({len(failures)} problem(s)):", file=sys.stderr)
        for f in failures:
            print(f"  FAIL: {f}", file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
