#!/usr/bin/env python3
"""CI regression gate over the hot-path microbench output.

Reads the ``BENCH_hotpath.json`` emitted by ``cargo bench --bench
micro_hotpath`` (a flat ``{op name: microseconds/op}`` object) and FAILS
(exit 1) when:

  * the incremental block-table serialization is not at least
    ``--min-table-speedup`` (default 5x) faster than the legacy
    from-scratch rebuild — the bar PR 1 introduced and ROADMAP records;
  * the incremental validity-mask serialization is not at least
    ``--min-mask-speedup`` (default 1.2x) faster than its rebuild. Both
    mask rows end in the same O(NB*B) consume pass (which dominates), so
    the achievable ratio is structurally far below the table pair's; the
    gate asserts the incremental path never regresses BELOW the rebuild
    rather than an unreachable 5x;
  * any gated op exceeds its absolute ceiling in ``CEILINGS_US`` —
    generous catastrophic-regression bounds (10-100x expected values),
    sized for noisy shared CI runners, not laptops;
  * the multi-worker engine's 4-worker aggregate decode throughput is
    not at least ``--min-engine-scaling`` (default 2.5x) the 1-worker
    number. The bench records the machine's core count alongside the two
    throughput rows; on runners with fewer than 4 cores the RATIO check
    is skipped (the parallelism physically is not there) while the
    rows' presence and absolute ceilings still apply;
  * any row the gate needs is missing (a silently renamed bench row must
    not turn the gate into a no-op).

With ``--slo`` the gate instead reads the ``BENCH_slo.json`` emitted by
``paged-eviction slo`` (schema ``slo-v1``) and FAILS when any gated
scenario is missing, reports fewer completions than requests, exceeds its
p99 TTFT/TPOT ceiling, misses its goodput/attainment floor, drops the
arena contention counters (``lock_acquisitions`` etc.), lacks the
``policy`` field (and, on ``--policy auto`` rows, a nonempty
``policy_counts`` breakdown of what the autotuner resolved), misses a
multi-worker contention-activity floor (``min_steals`` /
``min_cross_preempts`` / ``min_preemptions`` — waived on 1-worker rows),
or shows different output digests at different ``--workers`` counts (the
determinism contract the whole harness rides on). Ceilings/floors are
generous — sized for noisy shared CI runners — so a failure means a real
tail-latency or scheduling regression, not jitter.

Stdlib only — runs on a bare CI python with no installs.

Usage:
    python3 tools/bench_gate.py rust/BENCH_hotpath.json
    python3 tools/bench_gate.py --min-table-speedup 5 bench.json
    python3 tools/bench_gate.py --slo BENCH_slo.json
"""

import argparse
import json
import sys

TABLE_REBUILD = "block_table rebuild+consume (64 blocks)"
TABLE_INCR = "block_table incremental+consume (64 blocks)"
MASK_REBUILD = "valid_mask rebuild+consume (1024 slots)"
MASK_INCR = "valid_mask incremental+consume (1024 slots)"
ENGINE_1W = "engine decode throughput, 1 worker (us/token)"
ENGINE_4W = "engine decode throughput, 4 workers (us/token)"
CORES = "cpu cores available"

# Absolute per-op ceilings in microseconds. Deliberately loose: they exist
# to catch an accidental O(n) -> O(n^2) (or a stray allocation storm), not
# to police single-digit-percent noise.
CEILINGS_US = {
    TABLE_INCR: 5.0,
    MASK_INCR: 50.0,
    TABLE_REBUILD: 100.0,
    MASK_REBUILD: 500.0,
    "decode-step metadata cycle (paged, incremental)": 250.0,
    "paged post_append scan (32 blocks)": 250.0,
    "inverse_key_norm global scan (512 tokens)": 2000.0,
    # attention-feedback decode step: assemble the O(live) mass vector and
    # take the guided decision — same O(n) shape as the global scan above.
    "attn_feedback_step (512-pos mass + guided decision)": 2000.0,
    # one --policy auto resolution: lock-free pressure snapshot + pure
    # table choice + counter bump, paid once per SUBMIT, never per token.
    "autotune_pick (snapshot + choose + record)": 50.0,
    "JSON request parse": 500.0,
    "argmax (4096 logits)": 250.0,
    # prefix cache: hash a 4-block chain + probe the index (admission
    # cost), and the full hit-4-pages + one copy-on-write cycle. Both are
    # per-PREFILL costs, not per-token, so the ceilings are generous.
    "prefix_lookup chain+probe (4 blocks of 16)": 250.0,
    "cow_copy cycle (hit 4 blocks + make_private)": 2000.0,
    # session API teardown: a full submit + prefill + one decode round +
    # synchronous cancel (blocks back in the arena before it returns).
    # Per-request cost dominated by the sim prefill, hence the slack.
    "cancel_request (submit+prefill+cancel)": 2000.0,
    # one steady-state scheduler decode round through the FaultyBackend
    # wrapper with NO plan — the passthrough path must stay ~free, since
    # it sits on the hot path whenever fault injection is compiled in.
    "fault_passthrough decode step (no plan)": 500.0,
    # multi-worker engine: a work-stealing handoff is pure queue surgery
    # (steal_tail + inject, no block traffic) and must stay that cheap...
    "worker_handoff (steal_tail + inject)": 250.0,
    # ... while a cross-worker preemption cycle snapshots the victim into
    # the shared swap pool and restores it a round later — a per-PRESSURE
    # cost, not per-token, hence the slack.
    "cross_worker_preempt (preempt_min + restore round)": 5000.0,
    # batched arena primitives: one global lock acquisition moves 16
    # blocks either direction — per-BATCH costs, so even these generous
    # ceilings catch a slide back to lock-per-block.
    "alloc_batch_16 (alloc_many, one lock)": 50.0,
    "release_batch_16 (release_many, one lock)": 50.0,
    # 4 threads recycling blocks through per-worker slot caches; steady
    # state the global lock stays cold, so the per-pair cost must stay
    # near the uncontended single-alloc cost.
    "arena_contended_alloc (4 threads, cached)": 100.0,
    # aggregate sim decode through the engine; loose per-token bounds so
    # an accidental serialization (one giant lock) still trips them.
    ENGINE_1W: 2000.0,
    ENGINE_4W: 2000.0,
}


def check(rows, min_table_speedup, min_mask_speedup, min_engine_scaling=2.5):
    """Return (failures, report_lines) for a {op: us/op} mapping."""
    failures = []
    report = []
    bad_rows = set()  # report each missing/bad row once, not per consumer

    def lookup(name):
        v = rows.get(name)
        if v is None:
            if name not in bad_rows:
                bad_rows.add(name)
                failures.append(f"missing bench row: {name!r}")
        elif not isinstance(v, (int, float)) or v != v or v < 0:
            if name not in bad_rows:
                bad_rows.add(name)
                failures.append(f"non-numeric bench row: {name!r} = {v!r}")
            return None
        return v

    pairs = [
        ("block_table", TABLE_REBUILD, TABLE_INCR, min_table_speedup),
        ("valid_mask", MASK_REBUILD, MASK_INCR, min_mask_speedup),
    ]
    for label, rebuild_row, incr_row, floor in pairs:
        rebuild, incr = lookup(rebuild_row), lookup(incr_row)
        if rebuild is None or incr is None:
            continue
        speedup = rebuild / max(incr, 1e-9)
        line = f"{label}: rebuild {rebuild:.3f} us -> incremental {incr:.3f} us ({speedup:.1f}x, need >= {floor:.1f}x)"
        report.append(line)
        if speedup < floor:
            failures.append(f"speedup regression: {line}")

    for name, ceiling in sorted(CEILINGS_US.items()):
        v = lookup(name)
        if v is None:
            continue
        report.append(f"ceiling: {name}: {v:.3f} us (<= {ceiling:.1f} us)")
        if v > ceiling:
            failures.append(
                f"absolute regression: {name}: {v:.3f} us exceeds the {ceiling:.1f} us ceiling"
            )

    # multi-worker scaling: 4 workers over one shared arena must actually
    # saturate the cores. Only meaningful where 4 cores exist — the bench
    # reports the machine's parallelism so a 2-core runner skips the
    # ratio (the rows themselves are still required above/below).
    us1, us4, cores = lookup(ENGINE_1W), lookup(ENGINE_4W), lookup(CORES)
    if us1 is not None and us4 is not None and cores is not None:
        scaling = us1 / max(us4, 1e-9)
        if cores >= 4:
            line = (
                f"engine scaling: {us1:.3f} us/token (1w) -> {us4:.3f} us/token (4w) "
                f"({scaling:.2f}x, need >= {min_engine_scaling:.1f}x on {cores:.0f} cores)"
            )
            report.append(line)
            if scaling < min_engine_scaling:
                failures.append(f"scaling regression: {line}")
        else:
            report.append(
                f"engine scaling: {scaling:.2f}x observed, ratio check skipped "
                f"({cores:.0f} core(s) < 4)"
            )

    return failures, report


# Per-scenario SLO gates over BENCH_slo.json rows. The scenarios listed
# here are REQUIRED: a missing scenario fails the gate (a renamed or
# silently dropped scenario must not turn the gate into a no-op), exactly
# like the required-row discipline of the us/op gate above. Bounds are
# catastrophic-regression bounds for shared CI runners, not laptop noise
# police: the sim decodes in microseconds, so p99 TTFT in the seconds
# means head-of-line blocking or a scheduling livelock, and goodput near
# zero means the deadline math or the digest pipeline broke.
SLO_SCENARIOS = {
    "bursty-chat": {
        "max_ttft_p99_ms": 5000.0,
        "max_tpot_p99_ms": 500.0,
        "min_goodput_tok_s": 50.0,
        "min_attainment": 0.5,
    },
    "longbench-replay": {
        "max_ttft_p99_ms": 10000.0,
        "max_tpot_p99_ms": 1000.0,
        "min_goodput_tok_s": 5.0,
        "min_attainment": 0.5,
    },
    # Arena-pressure scenario (PR 9): 4 marathon requests outgrow a
    # deliberately undersized arena while a sprint backlog begs to be
    # stolen. The latency/goodput bounds are huge on purpose — the real
    # teeth are the min_* RATE FLOORS, which assert the multi-worker run
    # actually stole work and cross-preempted (i.e. the contention the
    # scenario is built to create really happened). Rate floors apply
    # ONLY to rows with workers > 1: at 1 worker the marathons run back
    # to back and nothing needs stealing.
    "saturate-steal": {
        "max_ttft_p99_ms": 60000.0,
        "max_tpot_p99_ms": 2000.0,
        "min_goodput_tok_s": 5.0,
        "min_attainment": 0.5,
        "min_steals": 1.0,
        "min_cross_preempts": 1.0,
        "min_preemptions": 1.0,
    },
}


def check_slo(data, gates=None):
    """Return (failures, report_lines) for a parsed BENCH_slo.json."""
    gates = SLO_SCENARIOS if gates is None else gates
    failures = []
    report = []
    if not isinstance(data, dict) or not isinstance(data.get("rows"), list):
        return ["slo JSON must be an object with a 'rows' list"], []

    by_scenario = {}
    for i, row in enumerate(data["rows"]):
        if not isinstance(row, dict) or not isinstance(row.get("scenario"), str):
            failures.append(f"slo row {i}: not an object naming a 'scenario'")
            continue
        by_scenario.setdefault(row["scenario"], []).append(row)

    def num(label, row, field):
        v = row.get(field)
        if isinstance(v, bool) or not isinstance(v, (int, float)) or v != v:
            failures.append(f"{label}: non-numeric field {field!r} = {v!r}")
            return None
        return v

    for name, g in sorted(gates.items()):
        rows = by_scenario.get(name)
        if not rows:
            failures.append(f"missing slo scenario: {name!r}")
            continue
        digests = []
        for row in rows:
            w = row.get("workers")
            label = f"{name} @ {w} worker(s)"
            d = row.get("digest")
            if isinstance(d, str) and d:
                digests.append((w, d))
            else:
                failures.append(f"{label}: missing output digest")
            completed = num(label, row, "completed")
            requests = num(label, row, "requests")
            if completed is not None and requests is not None and completed < requests:
                failures.append(
                    f"{label}: only {completed:.0f} of {requests:.0f} requests completed"
                )
            ttft = num(label, row, "ttft_p99_ms")
            if ttft is not None:
                report.append(
                    f"{label}: ttft p99 {ttft:.1f} ms (<= {g['max_ttft_p99_ms']:.0f} ms)"
                )
                if ttft > g["max_ttft_p99_ms"]:
                    failures.append(
                        f"tail regression: {label}: ttft p99 {ttft:.1f} ms exceeds "
                        f"the {g['max_ttft_p99_ms']:.0f} ms ceiling"
                    )
            tpot = num(label, row, "tpot_p99_ms")
            if tpot is not None:
                report.append(
                    f"{label}: tpot p99 {tpot:.2f} ms (<= {g['max_tpot_p99_ms']:.0f} ms)"
                )
                if tpot > g["max_tpot_p99_ms"]:
                    failures.append(
                        f"tail regression: {label}: tpot p99 {tpot:.2f} ms exceeds "
                        f"the {g['max_tpot_p99_ms']:.0f} ms ceiling"
                    )
            goodput = num(label, row, "goodput_tok_s")
            if goodput is not None:
                report.append(
                    f"{label}: goodput {goodput:.0f} tok/s (>= {g['min_goodput_tok_s']:.0f})"
                )
                if goodput < g["min_goodput_tok_s"]:
                    failures.append(
                        f"goodput regression: {label}: {goodput:.1f} tok/s is below "
                        f"the {g['min_goodput_tok_s']:.0f} tok/s floor"
                    )
            attainment = num(label, row, "slo_attainment")
            if attainment is not None:
                report.append(
                    f"{label}: slo attainment {attainment:.2f} (>= {g['min_attainment']:.2f})"
                )
                if attainment < g["min_attainment"]:
                    failures.append(
                        f"attainment regression: {label}: {attainment:.2f} is below "
                        f"the {g['min_attainment']:.2f} floor"
                    )
            # policy accounting (PR 10): every gated row names the policy
            # it replayed under, and an "auto" row must also break down
            # what the autotuner actually resolved per request — with the
            # sentinel itself never leaking through unresolved.
            pol = row.get("policy")
            if not isinstance(pol, str) or not pol:
                failures.append(f"{label}: missing 'policy' field")
            elif pol == "auto":
                pc = row.get("policy_counts")
                if not isinstance(pc, dict) or not pc:
                    failures.append(
                        f"{label}: auto row carries no 'policy_counts' breakdown"
                    )
                elif "auto" in pc:
                    failures.append(
                        f"{label}: 'auto' leaked into policy_counts unresolved"
                    )
                else:
                    picks = " ".join(f"{k}={v}" for k, v in sorted(pc.items()))
                    report.append(f"{label}: auto resolved {picks}")
            # arena contention counters (PR 9) are REQUIRED fields on
            # every gated row — a renamed counter must not silently
            # vanish from the perf trajectory.
            la = num(label, row, "lock_acquisitions")
            ca = num(label, row, "contended_acquisitions")
            cr = num(label, row, "cache_refills")
            cd = num(label, row, "cache_drains")
            if None not in (la, ca, cr, cd):
                report.append(
                    f"{label}: arena locks {la:.0f} ({ca:.0f} contended), "
                    f"refills {cr:.0f}, drains {cd:.0f}"
                )
            # contention-activity floors: only meaningful where peers
            # exist to steal from / preempt across, so single-worker
            # rows are exempt by construction.
            workers_n = w if isinstance(w, (int, float)) and not isinstance(w, bool) else None
            for floor_key, field in (
                ("min_steals", "steals"),
                ("min_cross_preempts", "cross_preempts"),
                ("min_preemptions", "preemptions"),
            ):
                floor = g.get(floor_key)
                if floor is None:
                    continue
                v = num(label, row, field)
                if v is None:
                    continue
                if workers_n is not None and workers_n > 1:
                    report.append(f"{label}: {field} {v:.0f} (>= {floor:.0f})")
                    if v < floor:
                        failures.append(
                            f"contention floor: {label}: {field} {v:.0f} is below "
                            f"the {floor:.0f} floor expected of a multi-worker run"
                        )
                else:
                    report.append(
                        f"{label}: {field} {v:.0f} (floor waived at {w} worker(s))"
                    )
        if len({d for _, d in digests}) > 1:
            failures.append(
                f"determinism violation: {name}: output digests diverge across "
                "worker counts: "
                + ", ".join(f"{w}w={d}" for w, d in digests)
            )

    return failures, report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("json_path", help="path to BENCH_hotpath.json (or BENCH_slo.json with --slo)")
    ap.add_argument("--min-table-speedup", type=float, default=5.0)
    ap.add_argument("--min-mask-speedup", type=float, default=1.2)
    ap.add_argument("--min-engine-scaling", type=float, default=2.5)
    ap.add_argument(
        "--slo",
        action="store_true",
        help="gate a BENCH_slo.json (per-scenario tail latency / goodput / digests) "
        "instead of the us/op microbench",
    )
    args = ap.parse_args(argv)

    try:
        with open(args.json_path, "r", encoding="utf-8") as f:
            rows = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot read {args.json_path}: {e}", file=sys.stderr)
        return 1

    if args.slo:
        failures, report = check_slo(rows)
    else:
        if not isinstance(rows, dict):
            print("bench gate: bench JSON must be an object of op -> us/op", file=sys.stderr)
            return 1
        failures, report = check(
            rows, args.min_table_speedup, args.min_mask_speedup, args.min_engine_scaling
        )
    for line in report:
        print(f"  {line}")
    if failures:
        print(f"\nbench gate FAILED ({len(failures)} problem(s)):", file=sys.stderr)
        for f in failures:
            print(f"  FAIL: {f}", file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
