//! API stub for the `xla` (xla-rs 0.1.6) PJRT bindings.
//!
//! The real bindings need the XLA C library, which is not present in the
//! offline build environment. This stub mirrors exactly the API surface
//! `paged_eviction`'s `runtime` module uses so the PJRT code keeps
//! type-checking under `--features xla`; every entry point fails with
//! [`Error::StubRuntime`] at runtime. Deployments with the real library
//! swap this path dependency for actual xla-rs (same signatures).

use std::fmt;

#[derive(Debug, Clone)]
pub enum Error {
    /// Raised by every stub entry point.
    StubRuntime(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubRuntime(what) => write!(
                f,
                "xla stub: {what} unavailable (built against the in-tree API stub; \
                 link the real xla-rs bindings to execute PJRT graphs)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &'static str) -> Result<T> {
    Err(Error::StubRuntime(what))
}

/// Element types a [`Literal`] can carry.
pub trait ArrayElement: Copy + 'static {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u32 {}

/// Host-side literal (stub: never holds device-ready data).
#[derive(Debug, Clone, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: ArrayElement>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal(())
    }
}

impl From<i32> for Literal {
    fn from(_v: i32) -> Literal {
        Literal(())
    }
}

impl From<i64> for Literal {
    fn from(_v: i64) -> Literal {
        Literal(())
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_buffer")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_stub() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("xla stub"), "{msg}");
    }
}
