//! Offline shim for the subset of the `anyhow` crate this workspace uses
//! (the vendor set has no crates.io access — see `rust/vendor/README.md`).
//!
//! Provides [`Error`] with a context chain, [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the [`Context`] extension
//! trait for `Result` and `Option`. Formatting matches real `anyhow` where
//! it matters to this repo: `{}` prints the outermost message, `{:#}`
//! prints the whole chain separated by `": "`, and `{:?}` prints the
//! message plus a "Caused by:" list.

use std::fmt::{self, Debug, Display};

/// Error with an optional chain of causes (outermost context first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message (no cause).
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    fn wrap<C: Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn to_msg(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// Identity wrapper matching `anyhow::Error::new`-ish call sites.
    pub fn context<C: Display>(self, ctx: C) -> Error {
        self.wrap(ctx)
    }
}

pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        let mut i = 0usize;
        while let Some(e) = cur {
            write!(f, "\n    {i}: {}", e.msg)?;
            cur = e.source.as_deref();
            i += 1;
        }
        Ok(())
    }
}

/// Any std error converts into `Error`, preserving its source chain as
/// context layers. (Error itself deliberately does NOT implement
/// `std::error::Error`, exactly like real anyhow, so this blanket impl
/// cannot overlap the reflexive `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_adds_layer() {
        let r: Result<()> = Err(anyhow!("inner {}", 1));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 1");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 2);
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("nope");
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{:#}", f(true).unwrap_err()), "failed with code 2");
        assert_eq!(format!("{}", g().unwrap_err()), "nope");
        let key = "k";
        assert_eq!(format!("{}", anyhow!("missing {key:?}")), "missing \"k\"");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
