//! Offline shim for the subset of the `log` crate this workspace uses
//! (levels, the `Log` trait, boxed-logger installation and the five level
//! macros). Semantics match real `log`: `Error` is the most severe level
//! and orders lowest, records are dropped unless they pass both the global
//! max level and the installed logger's `enabled` check.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn to_level_filter(&self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public API of real `log`, but the
/// macros below need a callable entry point.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    static SEEN: AtomicU32 = AtomicU32::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= Level::Info
        }
        fn log(&self, r: &Record) {
            if self.enabled(r.metadata()) {
                let _ = format!("[{}] {}", r.level(), r.args());
                SEEN.fetch_add(1, Ordering::Relaxed);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_order_like_real_log() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
        assert_eq!(Level::Warn.to_level_filter(), LevelFilter::Warn);
        assert_eq!(format!("{}", Level::Info), "INFO");
    }

    #[test]
    fn boxed_logger_receives_filtered_records() {
        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("dropped by max level");
        error!("urgent");
        assert_eq!(SEEN.load(Ordering::Relaxed), 2);
        assert!(set_boxed_logger(Box::new(Counter)).is_err(), "second install rejected");
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
