//! Scheduler integration on the deterministic sim backend (no PJRT):
//! batched decode rounds, O(1) shared-arena accounting, preemption under
//! memory pressure with recompute-on-readmission.
//!
//! The sim backend's logits are a pure function of token history, so
//! greedy outputs are bit-deterministic and independent of physical block
//! layout — which is what lets these tests pin (a) the batched round loop
//! against a per-sequence reference and (b) a contended, preempting run
//! against an uncontended one.

use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::BlockManager;
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::SimBackend;
use paged_eviction::scheduler::backend::{DecodeBackend, Prefilled};
use paged_eviction::scheduler::{FinishReason, Request, SchedConfig, Scheduler};
use paged_eviction::util::rng::Pcg32;

/// PR 2 semantics on purpose: hard-capacity watermarks (no hysteresis
/// band), a disabled swap pool and no prefix cache, so these tests keep
/// pinning the recompute-on-readmission path with exact arena
/// arithmetic. The swap/watermark behaviors are pinned in
/// `tests/swap_preempt.rs`, the prefix-cache behaviors in
/// `tests/prefix_cache.rs`.
fn cfg(page: usize, conc: usize, arena_blocks: usize) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        watermark_low: 1.0,
        watermark_high: 1.0,
        swap_bytes: 0,
        prefix_cache: false,
        ..SchedConfig::default()
    }
}

fn mk_req(id: u64, prompt: Vec<u32>, gen: usize, budget: usize, policy: &str) -> Request {
    let mut r = Request::new(id, prompt, gen);
    r.budget = budget;
    r.policy = policy.to_string();
    r
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

/// Per-sequence reference: drive the backend directly, one sequence at a
/// time, decoding through singleton `decode_batch` calls — the shape of
/// the old scheduler loop.
fn reference_tokens(page: usize, prompt: &[u32], gen: usize, budget: usize, policy: &str) -> Vec<u32> {
    let arena = BlockManager::new(100_000);
    let mut be = SimBackend::new(page);
    let Prefilled::Ready { mut seq, logits } = be
        .prefill(&arena, prompt, budget, make_policy(policy).unwrap())
        .unwrap()
    else {
        panic!("reference prefill OOM")
    };
    let mut tok = argmax(&logits);
    let mut out = Vec::new();
    for _ in 0..gen {
        out.push(tok);
        while !seq.cache.ensure_block() {
            be.grow_bucket(&mut seq).unwrap();
        }
        let mut batch = [(&mut seq, tok)];
        let logits = be.decode_batch(&mut batch).pop().unwrap().unwrap();
        tok = argmax(&logits);
    }
    out
}

#[test]
fn batched_rounds_match_per_sequence_reference() {
    // Mixed policies and budgets in one batch; ample arena so no
    // preemption muddies the comparison.
    let page = 4;
    let mut rng = Pcg32::new(42);
    let specs: Vec<(Vec<u32>, usize, usize, &str)> = vec![
        (rand_prompt(&mut rng, 33), 12, 16, "paged"),
        (rand_prompt(&mut rng, 48), 9, 24, "streaming"),
        (rand_prompt(&mut rng, 21), 15, 16, "inverse_key_norm"),
        (rand_prompt(&mut rng, 40), 7, 64, "full"),
        (rand_prompt(&mut rng, 27), 11, 16, "keydiff"),
    ];
    let mut sched = Scheduler::new_sim(cfg(page, 8, 10_000));
    for (i, (p, gen, budget, pol)) in specs.iter().enumerate() {
        sched.submit(mk_req(i as u64 + 1, p.clone(), *gen, *budget, pol));
    }
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    assert_eq!(outs.len(), specs.len());
    assert_eq!(sched.preemptions, 0, "ample arena must not preempt");
    for (o, (p, gen, budget, pol)) in outs.iter().zip(&specs) {
        let want = reference_tokens(page, p, *gen, *budget, pol);
        assert_eq!(
            o.tokens, want,
            "req {} ({pol}): batched rounds drifted from the per-sequence loop",
            o.id
        );
        assert_eq!(o.finish, FinishReason::MaxTokens);
    }
    assert_eq!(sched.live_blocks(), 0, "retired sequences freed the arena");
}

/// Engineered exhaustion: two "full"-policy sequences whose caches grow
/// every `page` steps, in an arena sized so mid-decode growth MUST run
/// dry. The youngest is preempted, the oldest finishes, the victim is
/// readmitted (recompute + replay) and must produce bit-identical tokens
/// to an uncontended run.
#[test]
fn exhaustion_preempts_youngest_and_readmission_reproduces_tokens() {
    let page = 4;
    let gen = 24;
    let mut rng = Pcg32::new(7);
    let pa = rand_prompt(&mut rng, 64); // 16 full blocks at prefill
    let pb = rand_prompt(&mut rng, 64);
    // The policy-aware gate charges each full-policy prefill its real 16
    // blocks (prompt 64 @ page 4, budget ignored by FullCache) and admits
    // both (32 <= 36); the ungated decode growth — ceil(24/4) = 6 blocks
    // each — then exceeds the arena, so preemption must reclaim it.
    let uncontended = {
        let mut s = Scheduler::new_sim(cfg(page, 2, 10_000));
        s.submit(mk_req(1, pa.clone(), gen, 16, "full"));
        s.submit(mk_req(2, pb.clone(), gen, 16, "full"));
        let mut outs = s.run_to_completion().unwrap();
        assert_eq!(s.preemptions, 0);
        outs.sort_by_key(|o| o.id);
        outs
    };

    let mut sched = Scheduler::new_sim(cfg(page, 2, 36));
    sched.submit(mk_req(1, pa, gen, 16, "full"));
    sched.submit(mk_req(2, pb, gen, 16, "full"));
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);

    assert!(
        sched.preemptions >= 1,
        "a 36-block arena cannot hold two growing 22-block sequences"
    );
    assert_eq!(outs.len(), 2);
    for (o, want) in outs.iter().zip(&uncontended) {
        assert_eq!(o.id, want.id);
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
        assert_eq!(
            o.tokens, want.tokens,
            "req {}: preempt -> requeue -> readmit must reproduce the uncontended output",
            o.id
        );
    }
    // the youngest (req 2) was the victim; the elder ran through
    assert_eq!(outs[0].preemptions, 0, "oldest sequence is never the victim");
    assert!(outs[1].preemptions >= 1, "youngest sequence was preempted");
    assert_eq!(outs[1].cache_stats.preemptions, outs[1].preemptions as u64);
    assert!(
        sched.arena().stats().peak_used <= 36,
        "arena capacity is a hard bound, not an estimate"
    );
    assert_eq!(sched.live_blocks(), 0);
}

#[test]
fn preemptions_surface_in_step_report() {
    let page = 4;
    let mut rng = Pcg32::new(9);
    let mut sched = Scheduler::new_sim(cfg(page, 2, 36));
    sched.submit(mk_req(1, rand_prompt(&mut rng, 64), 24, 16, "full"));
    sched.submit(mk_req(2, rand_prompt(&mut rng, 64), 24, 16, "full"));
    let mut preempted = 0;
    let mut decoded = 0;
    while !sched.is_idle() {
        let rep = sched.step().unwrap();
        preempted += rep.preempted;
        decoded += rep.decoded_tokens;
    }
    assert!(preempted >= 1, "StepReport must surface preemptions");
    assert!(decoded > 2 * 24, "replay decode work is reported too");
    assert_eq!(sched.preemptions, preempted as u64);
}

#[test]
fn zero_budget_requests_are_rejected_not_floored() {
    let mut sched = Scheduler::new_sim(cfg(4, 2, 64));
    sched.submit(mk_req(1, vec![1, 2, 3], 4, 0, "paged"));
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::Error);
    assert!(outs[0].tokens.is_empty());
}

#[test]
fn sub_page_budgets_are_clamped_to_one_page() {
    let mut rng = Pcg32::new(3);
    let mut sched = Scheduler::new_sim(cfg(4, 2, 64));
    sched.submit(mk_req(1, rand_prompt(&mut rng, 12), 4, 1, "paged"));
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs[0].finish, FinishReason::MaxTokens);
    assert_eq!(outs[0].tokens.len(), 4);
}

#[test]
fn impossible_requests_error_instead_of_livelocking() {
    // The packed prompt (min(400, 400) = 400 tokens = 100 blocks) can
    // never fit a 16-block arena. The estimate gate admits it once the
    // arena is idle, prefill reports OutOfMemory, and — with nothing
    // running that could ever free blocks — the scheduler must reject it
    // with an error instead of requeueing forever.
    let mut rng = Pcg32::new(4);
    let mut sched = Scheduler::new_sim(cfg(4, 2, 16));
    sched.submit(mk_req(1, rand_prompt(&mut rng, 400), 100, 400, "paged"));
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::Error);
}

#[test]
fn long_generation_with_small_budget_is_served_not_rejected() {
    // A worst-case reservation, ceil((16 + 120) / 4) = 34 blocks, exceeds
    // the 20-block arena; the admission gate charges only the 4-block
    // packed prompt, and the paged policy's decode eviction keeps the
    // real footprint at ~budget/B + slack — the request must run to
    // completion without ever being preempted, let alone rejected.
    let mut rng = Pcg32::new(8);
    let mut sched = Scheduler::new_sim(cfg(4, 2, 20));
    sched.submit(mk_req(1, rand_prompt(&mut rng, 32), 120, 16, "paged"));
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::MaxTokens);
    assert_eq!(outs[0].tokens.len(), 120);
    assert_eq!(outs[0].preemptions, 0, "bounded footprint never preempts");
}

/// Satellite: admission charges the PER-POLICY resident prompt. FullCache
/// keeps the whole prompt regardless of budget, so a `budget < prompt`
/// request must be gated on its real 16-block claim — the old
/// `min(prompt, budget)` estimate said 4 blocks, admitted it early, and
/// churned through a doomed prefill (claim 12 blocks, hit ArenaDry, free
/// them) every round until the elder sequence retired. Zero churn is
/// pinned through the arena's exact alloc count.
#[test]
fn full_cache_admission_charges_whole_prompt_not_budget() {
    let page = 4;
    let mut rng = Pcg32::new(12);
    let mut sched = Scheduler::new_sim(cfg(page, 2, 20));
    // elder: 8-block prompt + 2 blocks of growth = 10 blocks for 8 rounds
    sched.submit(mk_req(1, rand_prompt(&mut rng, 32), 8, 1024, "full"));
    // understated budget: resident is the full 64-token prompt (16 blocks),
    // which cannot fit next to the elder — must WAIT, not churn
    sched.submit(mk_req(2, rand_prompt(&mut rng, 64), 4, 16, "full"));
    let rep = sched.step().unwrap();
    assert_eq!(rep.prefilled, 1, "the full-policy claim 16 > 12 free: gated");
    assert_eq!(sched.running(), 1);
    assert_eq!(sched.pending(), 1);
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    assert_eq!(outs.len(), 2);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
    }
    assert_eq!(outs[0].tokens.len(), 8);
    assert_eq!(outs[1].tokens.len(), 4);
    assert_eq!(sched.preemptions, 0, "waiting, not thrash-admitting");
    // exact alloc ledger: elder 8 + 2, late 16 + 1 — and NOT ONE block of
    // churn from doomed prefill attempts (the old estimate's failure mode)
    assert_eq!(sched.arena().stats().allocs, 27, "zero admission churn");
    assert_eq!(sched.live_blocks(), 0);
}

#[test]
fn ttft_is_recorded_at_admission_even_for_single_token_outputs() {
    let mut rng = Pcg32::new(5);
    let mut sched = Scheduler::new_sim(cfg(4, 2, 64));
    sched.submit(mk_req(1, rand_prompt(&mut rng, 16), 1, 16, "paged"));
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs[0].tokens.len(), 1);
    assert!(
        outs[0].ttft_s > 0.0,
        "prefill produced the first token, so TTFT must be positive"
    );
}

#[test]
fn admission_is_optimistic_and_preemption_reclaims() {
    // Arena of 12 blocks; each request's prefill claims exactly 4 blocks
    // (budget 16, page 4). The old worst-case gate added the full
    // generation — ceil((16 + 24) / 4) = 10 blocks — and admitted one
    // request at a time; the admission gate now charges only what prefill
    // claims, so all three fit (3 * 4 = 12 <= capacity) and the
    // preemption path reclaims the optimism when decode growth outruns
    // the arena. The capacity bound stays hard either way.
    let page = 4;
    let mut rng = Pcg32::new(6);
    let mut sched = Scheduler::new_sim(cfg(page, 4, 12));
    for i in 0..3 {
        sched.submit(mk_req(i + 1, rand_prompt(&mut rng, 24), 24, 16, "paged"));
    }
    let rep = sched.step().unwrap();
    assert_eq!(rep.prefilled, 3, "prompt-footprint admission fits all three");
    assert!(rep.preempted >= 1, "growth past capacity preempts in-round");
    assert!(sched.live_blocks() > 0);
    assert!(sched.live_blocks() <= 12);
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 3);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
        assert_eq!(o.tokens.len(), 24);
    }
    assert!(
        sched.arena().stats().peak_used <= 12,
        "optimistic admission must not break the physical bound"
    );
    assert_eq!(sched.live_blocks(), 0);
}
