//! Multi-worker engine: N scheduler threads over ONE shared arena, ONE
//! swap pool, ONE prefix index and ONE admission-serial source — and
//! per-request outputs that are bit-identical to the single-threaded
//! scheduler no matter how placement, stealing or cross-worker
//! preemption distribute the work.
//!
//! The twin-run legs run the SAME materialized request list at
//! `workers` ∈ {1, 2, 4} and compare every request's token stream. The
//! sim backend's logits are a pure function of token history, greedy
//! decode is placement-independent, and preemption (restore-or-replay)
//! is lossless — so any drift is an engine bug, not scheduling noise.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use paged_eviction::api::{RequestBuilder, SeqEvent};
use paged_eviction::runtime::{FaultPlan, SimBackend};
use paged_eviction::scheduler::{
    EngineReport, FinishReason, MultiEngine, Priority, Request, RequestOutput, SchedConfig,
    Scheduler,
};
use paged_eviction::util::rng::Pcg32;

fn cfg(page: usize, conc: usize, arena_blocks: usize, workers: usize) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        watermark_low: 0.7,
        watermark_high: 0.85,
        swap_bytes: 1 << 26,
        prefix_cache: true,
        workers,
        ..SchedConfig::default()
    }
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

/// The mixed-pressure workload every twin-run leg replays: shared
/// prefixes (prefix index + CoW), mixed policies and budgets (hole
/// punching), prompts and generations sized so the small arena MUST
/// preempt. Materialized up front so every leg submits byte-identical
/// requests in the same order.
fn pressure_workload() -> Vec<RequestBuilder> {
    let mut rng = Pcg32::new(2024);
    let shared = rand_prompt(&mut rng, 16); // 4 shared pages at page=4
    // every registry entry plus the autotuner sentinel: the sim backend's
    // token streams are policy-invariant, so mixed (even auto-resolved)
    // policies must still twin bit-identically at any worker count
    let policies: Vec<&'static str> = paged_eviction::eviction::REGISTRY
        .iter()
        .map(|i| i.name)
        .chain(std::iter::once(paged_eviction::eviction::AUTO_POLICY))
        .collect();
    (0..10)
        .map(|i| {
            let mut prompt = if i % 2 == 0 { shared.clone() } else { Vec::new() };
            prompt.extend(rand_prompt(&mut rng, 24 + (i % 5) * 8));
            RequestBuilder::new(prompt)
                .max_new_tokens(8 + (i % 4) * 6)
                .policy(policies[i % policies.len()])
                .budget(if i % 3 == 0 { 9999 } else { 48 })
                .priority(match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Normal,
                    _ => Priority::Low,
                })
        })
        .collect()
}

/// Submit `builders` to a fresh engine, run to completion, assert the
/// shared pools drained to zero, and return (outputs by id, report).
fn run_leg(
    cfg: SchedConfig,
    builders: Vec<RequestBuilder>,
) -> (HashMap<u64, RequestOutput>, EngineReport) {
    let page = cfg.page_size;
    let mut engine = MultiEngine::new(cfg, move |_| SimBackend::new(page));
    for b in builders {
        engine.submit_builder(b).expect("submit");
    }
    let outs = engine.run_to_completion();
    assert_eq!(
        engine.arena().used(),
        0,
        "refcounted release must drain the shared arena at any worker count"
    );
    assert_eq!(engine.swap_pool().len(), 0, "no snapshot may outlive its request");
    assert_eq!(engine.swap_pool().used_bytes(), 0, "swap byte accounting must return to zero");
    let (report, _backends) = engine.shutdown(Duration::from_secs(5));
    let by_id: HashMap<u64, RequestOutput> = outs.into_iter().map(|o| (o.id, o)).collect();
    (by_id, report)
}

fn assert_same_outputs(
    base: &HashMap<u64, RequestOutput>,
    other: &HashMap<u64, RequestOutput>,
    what: &str,
) {
    assert_eq!(base.len(), other.len(), "{what}: request count drifted");
    for (id, b) in base {
        let o = &other[id];
        assert_eq!(b.tokens, o.tokens, "{what}: req {id} tokens drifted");
        assert_eq!(b.finish, o.finish, "{what}: req {id} finish reason drifted");
    }
}

/// Tentpole invariant: the twin-run matrix. The same pressured workload
/// (forced preemption, shared prefixes, mixed priorities) produces
/// bit-identical per-request outputs at 1, 2 and 4 workers.
#[test]
fn twin_run_matrix_outputs_bit_identical_under_pressure() {
    let (base, base_report) = run_leg(cfg(4, 6, 24, 1), pressure_workload());
    assert_eq!(base.len(), 10);
    let preempted: u64 = base_report.workers.iter().map(|w| w.preemptions).sum();
    assert!(preempted >= 1, "the workload must actually pressure the arena");
    for workers in [2, 4] {
        let (outs, report) = run_leg(cfg(4, 6, 24, workers), pressure_workload());
        assert_eq!(report.workers.len(), workers);
        assert_same_outputs(&base, &outs, &format!("workers={workers}"));
    }
}

/// A prefix published by one worker's prefill is a refcount hit for
/// every other worker — and retirement reclaims the shared blocks
/// exactly (the arena returns to zero).
#[test]
fn shared_prefix_spans_workers_and_reclaims_exactly() {
    let mk = || {
        let mut rng = Pcg32::new(99);
        let shared = rand_prompt(&mut rng, 32); // 8 shared pages at page=4
        (0..12)
            .map(|i| {
                let mut prompt = shared.clone();
                prompt.extend(rand_prompt(&mut rng, 16));
                RequestBuilder::new(prompt)
                    .max_new_tokens(6 + (i % 3) * 4)
                    .policy("full")
                    .budget(9999)
            })
            .collect::<Vec<_>>()
    };
    let (base, _) = run_leg(cfg(4, 4, 400, 1), mk());
    let (outs, report) = run_leg(cfg(4, 4, 400, 4), mk());
    assert_same_outputs(&base, &outs, "shared-prefix leg");
    let hits: u64 = report.workers.iter().map(|w| w.prefix_hit_blocks).sum();
    assert!(
        hits >= 8,
        "later prefills must hit the shared 8-page prefix across workers (got {hits})"
    );
    for (_, o) in outs {
        assert_eq!(o.finish, FinishReason::MaxTokens);
    }
}

/// Chaos leg: recoverable injected faults (transient decode faults and a
/// batch failure) leave outputs bit-identical across worker counts —
/// fault lanes are per-worker-stable and every recovery path is
/// lossless.
#[test]
fn chaos_twin_run_with_transient_faults_stays_identical() {
    let spec = "transient@r2s4,batch@6";
    let run = |workers: usize| {
        let plan = FaultPlan::parse(spec).expect("fault spec");
        let mut engine = MultiEngine::new_sim_faulty(cfg(4, 6, 24, workers), plan);
        for b in pressure_workload() {
            engine.submit_builder(b).expect("submit");
        }
        let outs = engine.run_to_completion();
        assert_eq!(engine.arena().used(), 0);
        let (report, _backends) = engine.shutdown(Duration::from_secs(5));
        let by_id: HashMap<u64, RequestOutput> = outs.into_iter().map(|o| (o.id, o)).collect();
        (by_id, report)
    };
    let (base, base_report) = run(1);
    assert_eq!(base.len(), 10);
    let retries: u64 = base_report.workers.iter().map(|w| w.fault_retries).sum();
    assert!(retries >= 1, "the fault plan must actually fire in the baseline");
    let (outs, _) = run(4);
    assert_same_outputs(&base, &outs, "chaos workers=4");
}

/// Cancellation fans out to the owning worker wherever the request lives
/// (placement and stealing move entries behind the caller's back), and
/// the survivors' outputs stay bit-identical across worker counts. The
/// cancelled requests carry a huge generation budget so the cancel
/// always lands while they are live — deterministically — at any count.
#[test]
fn cancel_reaches_owning_worker_and_survivors_match() {
    let mk = || {
        let mut rng = Pcg32::new(7);
        (0..8)
            .map(|i| {
                let b = RequestBuilder::new(rand_prompt(&mut rng, 24)).policy("paged").budget(48);
                if i == 2 || i == 5 {
                    b.max_new_tokens(200_000) // can never finish before the cancel
                } else {
                    b.max_new_tokens(12)
                }
            })
            .collect::<Vec<_>>()
    };
    let run = |workers: usize| {
        let mut engine = MultiEngine::new(cfg(4, 8, 400, workers), |_| SimBackend::new(4));
        let mut doomed = Vec::new();
        for (i, b) in mk().into_iter().enumerate() {
            let id = engine.submit_builder(b).expect("submit");
            if i == 2 || i == 5 {
                doomed.push(id.raw());
            }
        }
        for id in &doomed {
            // the Submit message may still be in the owner's inbox;
            // retry until the cancel finds it (it can never finish)
            let t0 = Instant::now();
            while !engine.cancel(*id) {
                assert!(t0.elapsed() < Duration::from_secs(10), "cancel never landed");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        let outs = engine.run_to_completion();
        assert_eq!(engine.arena().used(), 0, "cancel must free the arena");
        assert_eq!(engine.swap_pool().len(), 0);
        let (report, _) = engine.shutdown(Duration::from_secs(5));
        let cancelled: u64 = report.workers.iter().map(|w| w.cancelled).sum();
        assert_eq!(cancelled, 2, "both cancels must land on their owning worker");
        outs.into_iter().map(|o| (o.id, o)).collect::<HashMap<_, _>>()
    };
    let base = run(1);
    assert_eq!(base.len(), 6, "the two doomed requests emit no output");
    assert!(!base.contains_key(&3) && !base.contains_key(&6));
    let outs = run(4);
    assert_same_outputs(&base, &outs, "cancel survivors");
}

/// Work stealing: one worker saddled with a marathon request and a
/// backlog donates queue-tail entries to peers it observes idle — the
/// steal counter moves and every request still finishes with the same
/// tokens as the single-worker run.
#[test]
fn skewed_load_donates_work_to_idle_workers() {
    let mk = || {
        let mut rng = Pcg32::new(11);
        (0..16)
            .map(|i| {
                RequestBuilder::new(rand_prompt(&mut rng, 16))
                    .max_new_tokens(if i == 0 { 4000 } else { 2 })
                    .policy("paged")
                    .budget(48)
            })
            .collect::<Vec<_>>()
    };
    // concurrency 1: the marathon's worker cannot interleave its backlog
    let (base, _) = run_leg(cfg(4, 1, 800, 1), mk());
    let (outs, report) = run_leg(cfg(4, 1, 800, 4), mk());
    assert_same_outputs(&base, &outs, "skewed-load leg");
    assert!(
        report.steals >= 1,
        "short requests queued behind the marathon must be donated to idle workers"
    );
}

/// Cross-worker preemption: a worker whose admission is gated by the
/// shared watermark while ANOTHER worker holds the arena posts reclaim
/// pressure, and the worker owning the global
/// `(priority, Reverse(admit_serial))`-min victim preempts it into the
/// shared swap pool. Outputs still match the single-worker run.
///
/// Shape: a budget-capped marathon (~15 of the 16 arena blocks for
/// thousands of rounds) and a short request that can NEVER co-reside
/// with it. The short one is submitted only after the marathon's
/// `Prefilled` event, so at 2 workers its (idle) owner is forced through
/// the gate → pressure-channel → cross-preempt path.
#[test]
fn admission_pressure_preempts_across_workers() {
    let mk_cfg = |workers| SchedConfig {
        model: "sim".into(),
        page_size: 4,
        max_concurrency: 2,
        max_live_blocks: 16,
        watermark_low: 0.6,
        watermark_high: 1.0,
        swap_bytes: 1 << 26,
        prefix_cache: false,
        workers,
        ..SchedConfig::default()
    };
    let mk_reqs = || {
        let mut rng = Pcg32::new(5);
        vec![
            RequestBuilder::new(rand_prompt(&mut rng, 40))
                .max_new_tokens(20_000)
                .policy("paged")
                .budget(56)
                .stream_events(true),
            RequestBuilder::new(rand_prompt(&mut rng, 28))
                .max_new_tokens(16)
                .policy("paged")
                .budget(56),
        ]
    };
    let run = |workers: usize| {
        let mut engine = MultiEngine::new(mk_cfg(workers), |_| SimBackend::new(4));
        let mut reqs = mk_reqs().into_iter();
        engine.submit_builder(reqs.next().unwrap()).expect("submit");
        // hold the second submission until the marathon is decoding, so
        // its worker observes a held arena with thousands of rounds left
        let t0 = Instant::now();
        loop {
            match engine.next_event(Duration::from_millis(50)) {
                Some((1, SeqEvent::Prefilled { .. })) => break,
                Some(_) => {}
                None => assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "marathon never prefilled"
                ),
            }
        }
        engine.submit_builder(reqs.next().unwrap()).expect("submit");
        let outs = engine.run_to_completion();
        assert_eq!(engine.arena().used(), 0);
        let cross = engine.cross_preempts();
        let _ = engine.shutdown(Duration::from_secs(5));
        (outs.into_iter().map(|o| (o.id, o)).collect::<HashMap<_, _>>(), cross)
    };
    let (base, _) = run(1);
    assert_eq!(base.len(), 2);
    let (outs, cross) = run(2);
    assert_same_outputs(&base, &outs, "cross-preempt leg");
    assert!(
        cross >= 1,
        "the gated worker must reclaim through the shared pressure channel"
    );
}

/// Satellite: the admission claim scan (`kept_entries` over the whole
/// prompt) runs ONCE per request even when the low-watermark gate makes
/// the scheduler re-attempt the same admission round after round — the
/// block count is memoized on the queue entry (`ClaimMemo`) and the
/// scan's kept-entry artifact rides the entry to the prefill that
/// consumes it.
#[test]
fn admission_claim_scan_is_memoized_across_gated_retries() {
    let mut sched = Scheduler::new_sim(SchedConfig {
        model: "sim".into(),
        page_size: 4,
        max_concurrency: 4,
        // req 1 alone (10 -> 20 blocks of 32) sits above the low mark
        // (16), so reqs 2 and 3 are popped, gated and requeued on EVERY
        // round of its 40-token generation
        max_live_blocks: 32,
        watermark_low: 0.5,
        watermark_high: 1.0,
        swap_bytes: 0,
        prefix_cache: false,
        workers: 1,
        ..SchedConfig::default()
    });
    let mut rng = Pcg32::new(3);
    for id in 1..=3u64 {
        let mut r = Request::new(id, rand_prompt(&mut rng, 40), 40);
        r.policy = "full".into();
        r.budget = 9999;
        sched.submit(r);
    }
    let outs = sched.run_to_completion().expect("run");
    assert_eq!(outs.len(), 3);
    assert!(outs.iter().all(|o| o.finish == FinishReason::MaxTokens));
    assert_eq!(sched.preemptions, 0, "gating (not preemption) must serialize this workload");
    assert_eq!(
        sched.backend().policy_scans(),
        3,
        "one policy scan per request: gated retries reuse the memo, the \
         admitting prefill consumes the plan instead of rescanning"
    );
    assert_eq!(
        sched.backend().claim_calls(),
        3,
        "gated retries must not even reach the backend: the block count \
         is served from the ClaimMemo"
    );
}
