//! Session-API integration on the deterministic sim backend: the event
//! stream vs the legacy drain (bit-identity), server-assigned ids,
//! priority-aware admission + victim selection, synchronous cancellation
//! (exact arena reclaim, shared-page safety, swapped-out victims), step
//! deadlines, and admission-claim memoization.

use paged_eviction::api::{
    HandleState, RequestBuilder, RequestHandle, RequestId, SeqEvent, Session,
};
use paged_eviction::runtime::SimBackend;
use paged_eviction::scheduler::{
    FinishReason, Priority, Request, RequestOutput, SchedConfig, Scheduler,
};
use paged_eviction::util::propcheck::{self, PropConfig};
use paged_eviction::util::rng::Pcg32;

/// Hard-capacity watermarks, no swap, no prefix cache: the exact-
/// arithmetic baseline (individual tests open features up).
fn cfg(page: usize, conc: usize, arena_blocks: usize) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        watermark_low: 1.0,
        watermark_high: 1.0,
        swap_bytes: 0,
        prefix_cache: false,
        ..SchedConfig::default()
    }
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

/// Tokens carried by the stream's `Token` events, in order.
fn stream_tokens(events: &[SeqEvent]) -> Vec<u32> {
    events
        .iter()
        .filter_map(|e| match e {
            SeqEvent::Token { tok, .. } => Some(*tok),
            _ => None,
        })
        .collect()
}

fn finished_of(events: &[SeqEvent]) -> Option<RequestOutput> {
    events.iter().find_map(|e| match e {
        SeqEvent::Finished(o) => Some(o.clone()),
        _ => None,
    })
}

/// Drive a session to idle, draining every handle's events as they come.
fn run_session(
    session: &Session<SimBackend>,
    handles: &[RequestHandle<SimBackend>],
) -> Vec<Vec<SeqEvent>> {
    let mut streams: Vec<Vec<SeqEvent>> = vec![Vec::new(); handles.len()];
    while !session.is_idle() {
        session.step().unwrap();
        for (h, s) in handles.iter().zip(streams.iter_mut()) {
            s.extend(h.drain());
        }
    }
    for (h, s) in handles.iter().zip(streams.iter_mut()) {
        s.extend(h.drain());
    }
    streams
}

/// ACCEPTANCE: greedy outputs are bit-identical between the event-stream
/// API and the legacy `take_finished` drain — same trace through both,
/// ample arena (no preemption) with mixed per-request policies/budgets.
#[test]
fn event_stream_matches_legacy_drain_bit_identical() {
    let page = 4;
    let mut rng = Pcg32::new(42);
    let specs: Vec<(Vec<u32>, usize, usize, &str)> = vec![
        (rand_prompt(&mut rng, 33), 12, 16, "paged"),
        (rand_prompt(&mut rng, 48), 9, 24, "streaming"),
        (rand_prompt(&mut rng, 21), 15, 16, "inverse_key_norm"),
        (rand_prompt(&mut rng, 40), 7, 64, "full"),
        (rand_prompt(&mut rng, 27), 11, 16, "keydiff"),
    ];

    // legacy path: caller-assigned ids, blocking drain
    let mut legacy = Scheduler::new_sim(cfg(page, 8, 10_000));
    for (i, (p, gen, budget, pol)) in specs.iter().enumerate() {
        let mut r = Request::new(i as u64 + 1, p.clone(), *gen);
        r.budget = *budget;
        r.policy = pol.to_string();
        legacy.submit(r);
    }
    let mut legacy_outs = legacy.run_to_completion().unwrap();
    legacy_outs.sort_by_key(|o| o.id);

    // session path: server-assigned ids (same order => same 1..n)
    let session = Session::new_sim(cfg(page, 8, 10_000));
    let handles: Vec<_> = specs
        .iter()
        .map(|(p, gen, budget, pol)| {
            session
                .submit(
                    RequestBuilder::new(p.clone())
                        .max_new_tokens(*gen)
                        .budget(*budget)
                        .policy(*pol),
                )
                .unwrap()
        })
        .collect();
    let streams = run_session(&session, &handles);

    for ((h, s), legacy_out) in handles.iter().zip(&streams).zip(&legacy_outs) {
        assert_eq!(h.id().raw(), legacy_out.id, "submit order assigns 1..n");
        let out = finished_of(s).expect("stream must terminate in Finished");
        assert!(
            matches!(s.first(), Some(SeqEvent::Prefilled { ttft_s }) if *ttft_s > 0.0),
            "stream must open with Prefilled{{ttft > 0}}, got {:?}",
            s.first()
        );
        assert_eq!(
            stream_tokens(s),
            out.tokens,
            "req {}: concatenated Token events ARE the output",
            out.id
        );
        assert_eq!(out.tokens, legacy_out.tokens, "req {}: stream drifted", out.id);
        assert_eq!(out.finish, legacy_out.finish);
        assert!(h.is_done());
        assert_eq!(h.state(), HandleState::Finished);
    }
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0);
}

/// ACCEPTANCE (preemption leg): the same bit-identity holds under forced
/// preemption — replayed tokens are never re-emitted, and the victim's
/// stream shows Preempted/Resumed.
#[test]
fn event_stream_bit_identical_under_forced_preemption() {
    let page = 4;
    let gen = 24;
    let mut rng = Pcg32::new(7);
    let pa = rand_prompt(&mut rng, 64);
    let pb = rand_prompt(&mut rng, 64);

    let mut legacy = Scheduler::new_sim(cfg(page, 2, 36));
    for (i, p) in [&pa, &pb].iter().enumerate() {
        let mut r = Request::new(i as u64 + 1, (*p).clone(), gen);
        r.budget = 16;
        r.policy = "full".into();
        legacy.submit(r);
    }
    let mut legacy_outs = legacy.run_to_completion().unwrap();
    legacy_outs.sort_by_key(|o| o.id);
    assert!(legacy.preemptions >= 1, "36 blocks cannot hold both");

    let session = Session::new_sim(cfg(page, 2, 36));
    let handles: Vec<_> = [&pa, &pb]
        .iter()
        .map(|p| {
            session
                .submit(
                    RequestBuilder::new((*p).clone())
                        .max_new_tokens(gen)
                        .budget(16)
                        .policy("full"),
                )
                .unwrap()
        })
        .collect();
    let streams = run_session(&session, &handles);
    let n_preempted: usize = streams[1]
        .iter()
        .filter(|e| matches!(e, SeqEvent::Preempted { .. }))
        .count();
    let n_resumed: usize = streams[1]
        .iter()
        .filter(|e| matches!(e, SeqEvent::Resumed))
        .count();
    assert!(n_preempted >= 1, "the younger sequence must be preempted");
    assert_eq!(n_preempted, n_resumed, "every Preempted pairs with a Resumed");
    for (s, legacy_out) in streams.iter().zip(&legacy_outs) {
        let out = finished_of(s).expect("finished");
        assert_eq!(
            stream_tokens(s),
            out.tokens,
            "req {}: replayed tokens must not be re-emitted",
            out.id
        );
        assert_eq!(out.tokens, legacy_out.tokens, "req {}", out.id);
    }
}

/// SATELLITE: server-assigned ids never collide — across batches, cancels
/// and reuse — and cancelling an unknown or finished id is a clean no-op.
#[test]
fn server_assigned_ids_never_collide_and_cancel_is_clean_noop() {
    let mut rng = Pcg32::new(3);
    let session = Session::new_sim(cfg(4, 4, 10_000));
    let mut seen = std::collections::HashSet::new();
    let mut last_handle = None;
    for round in 0..3 {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                session
                    .submit(RequestBuilder::new(rand_prompt(&mut rng, 16)).max_new_tokens(3))
                    .unwrap()
            })
            .collect();
        for h in &handles {
            assert!(seen.insert(h.id()), "duplicate id {} in round {round}", h.id());
        }
        // cancel one mid-flight; its id is spent, never recycled
        session.step().unwrap();
        assert!(handles[0].cancel(), "running/queued request is cancellable");
        assert!(!handles[0].cancel(), "double cancel is a no-op");
        session.run_until_idle().unwrap();
        last_handle = Some(handles[7].clone());
    }
    assert_eq!(seen.len(), 24);
    // unknown and finished ids: clean no-ops, not panics
    assert!(!session.cancel(RequestId(999_999)));
    let h = last_handle.unwrap();
    assert_eq!(h.state(), HandleState::Finished);
    assert!(!h.cancel(), "cancelling a finished request is a no-op");
    assert_eq!(session.with_scheduler(|s| s.cancelled()), 3);
}

/// Priority-aware admission: with one slot, the High submission admitted
/// ahead of the earlier-queued Low one.
#[test]
fn high_priority_jumps_the_admission_queue() {
    let mut rng = Pcg32::new(5);
    let session = Session::new_sim(cfg(4, 1, 10_000));
    let low = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 16))
                .max_new_tokens(4)
                .priority(Priority::Low),
        )
        .unwrap();
    let high = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 16))
                .max_new_tokens(4)
                .priority(Priority::High),
        )
        .unwrap();
    session.step().unwrap();
    assert!(
        matches!(high.poll(), Some(SeqEvent::Prefilled { .. })),
        "the High request must be admitted first"
    );
    assert!(low.poll().is_none(), "the Low request is still queued");
    session.run_until_idle().unwrap();
    assert!(matches!(finished_of(&low.drain()), Some(o) if o.finish == FinishReason::MaxTokens));
}

/// ACCEPTANCE: a High-priority request admitted under memory pressure
/// preempts a Low-priority victim — never the reverse. The Low request is
/// the ELDER here, so the old youngest-first rule would have victimized
/// the High one.
#[test]
fn high_priority_preempts_low_victim_never_the_reverse() {
    let page = 4;
    let gen = 24;
    let mut rng = Pcg32::new(9);
    let pa = rand_prompt(&mut rng, 64);
    let pb = rand_prompt(&mut rng, 64);

    // uncontended references
    let solo = |p: &[u32]| {
        let mut s = Scheduler::new_sim(cfg(page, 1, 10_000));
        let mut r = Request::new(1, p.to_vec(), gen);
        r.budget = 16;
        r.policy = "full".into();
        s.submit(r);
        s.run_to_completion().unwrap().pop().unwrap().tokens
    };
    let want_a = solo(&pa);
    let want_b = solo(&pb);

    let session = Session::new_sim(cfg(page, 2, 36));
    let low = session
        .submit(
            RequestBuilder::new(pa)
                .max_new_tokens(gen)
                .budget(16)
                .policy("full")
                .priority(Priority::Low),
        )
        .unwrap();
    let high = session
        .submit(
            RequestBuilder::new(pb)
                .max_new_tokens(gen)
                .budget(16)
                .policy("full")
                .priority(Priority::High),
        )
        .unwrap();
    let streams = run_session(&session, &[low.clone(), high.clone()]);

    let out_low = finished_of(&streams[0]).unwrap();
    let out_high = finished_of(&streams[1]).unwrap();
    assert!(
        out_low.preemptions >= 1,
        "the Low request pays for the memory pressure"
    );
    assert_eq!(
        out_high.preemptions, 0,
        "the High request must NEVER be the victim while a Low one runs"
    );
    assert!(streams[1].iter().all(|e| !matches!(e, SeqEvent::Preempted { .. })));
    assert_eq!(out_low.tokens, want_a, "preempted Low output is lossless");
    assert_eq!(out_high.tokens, want_b);
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0);
}

/// SATELLITE (property): cancelling at a random step mid-decode returns
/// the arena to EXACTLY the state of a twin run in which the cancelled
/// request never existed — shared prefix pages a live sharer holds
/// survive by refcount (the hard-error arena would panic on any bad
/// free), and the survivor's output is untouched.
#[test]
fn property_cancel_restores_the_no_b_arena_exactly() {
    // drawn from the registry so new policies join the property the day
    // they register
    let pols: Vec<&'static str> =
        paged_eviction::eviction::REGISTRY.iter().map(|i| i.name).collect();
    propcheck::check(
        "cancel == B never existed",
        &PropConfig { cases: 24, ..Default::default() },
        |rng| {
            let page = [4usize, 8][rng.below(2) as usize];
            let pol_a = pols[rng.below(pols.len() as u32) as usize];
            let pol_b = pols[rng.below(pols.len() as u32) as usize];
            let prefix_len = page * (2 + rng.below(3) as usize);
            let prefix: Vec<u32> = (0..prefix_len).map(|_| rng.below(200)).collect();
            let mut prompt_a = prefix.clone();
            prompt_a.extend((0..8 + rng.below(24)).map(|_| rng.below(200)));
            let mut prompt_b = prefix;
            prompt_b.extend((0..8 + rng.below(24)).map(|_| rng.below(200)));
            let gen_a = 8 + rng.below(24) as usize;
            let gen_b = 8 + rng.below(24) as usize;
            let budget = page * (2 + rng.below(6) as usize);
            // cancel strictly mid-flight: B finishes no earlier than round
            // gen_b, so any step below that keeps it live
            let cancel_after = 1 + rng.below(gen_b as u32 - 2) as u64;
            let mk_cfg = || SchedConfig {
                prefix_cache: true,
                ..cfg(page, 4, 4096)
            };
            let submit_a = |s: &Session<SimBackend>| {
                s.submit(
                    RequestBuilder::new(prompt_a.clone())
                        .max_new_tokens(gen_a)
                        .budget(budget)
                        .policy(pol_a),
                )
                .unwrap()
            };

            // twin: A alone
            let twin = Session::new_sim(mk_cfg());
            let ha2 = submit_a(&twin);
            for _ in 0..cancel_after {
                twin.step().unwrap();
            }
            let used_twin = twin.with_scheduler(|s| s.arena().used());

            // real run: A + B, B cancelled at the same step
            let run = Session::new_sim(mk_cfg());
            let ha1 = submit_a(&run);
            let hb = run
                .submit(
                    RequestBuilder::new(prompt_b.clone())
                        .max_new_tokens(gen_b)
                        .budget(budget)
                        .policy(pol_b),
                )
                .unwrap();
            for _ in 0..cancel_after {
                run.step().unwrap();
            }
            if !hb.cancel() {
                return Err(format!("B (gen {gen_b}) not cancellable at step {cancel_after}"));
            }
            let used_now = run.with_scheduler(|s| s.arena().used());
            if used_now != used_twin {
                return Err(format!(
                    "cancel leaked: used {used_now} != twin {used_twin} \
                     (page {page}, a={pol_a}, b={pol_b}, step {cancel_after})"
                ));
            }
            if hb.state() != HandleState::Cancelled {
                return Err("cancelled handle must report Cancelled".into());
            }
            if hb.drain().iter().any(|e| matches!(e, SeqEvent::Finished(_))) {
                return Err("a cancelled request must emit no Finished".into());
            }
            // survivor unaffected (and drop-time arena checks all pass)
            run.run_until_idle().unwrap();
            twin.run_until_idle().unwrap();
            let toks = |h: &RequestHandle<SimBackend>| {
                finished_of(&h.drain()).map(|o| o.tokens).unwrap_or_default()
            };
            let (a_run, a_twin) = (toks(&ha1), toks(&ha2));
            if a_run != a_twin {
                return Err(format!("survivor output changed: {a_run:?} vs {a_twin:?}"));
            }
            let leftovers = run.with_scheduler(|s| s.arena().used());
            if leftovers != 0 {
                return Err(format!("{leftovers} blocks leaked at idle"));
            }
            if run.with_scheduler(|s| s.cancelled()) != 1 {
                return Err("cancel count must be 1".into());
            }
            Ok(())
        },
    );
}

/// Cancelling a sharer that holds live shared prefix pages: the hits are
/// real (pinned nonzero), the survivor keeps decoding on the shared
/// pages, and teardown frees only the cancelled request's claims.
#[test]
fn cancel_sharer_keeps_survivors_shared_pages_alive() {
    let page = 4;
    let mut rng = Pcg32::new(21);
    let prefix = rand_prompt(&mut rng, 4 * page);
    let mut pa = prefix.clone();
    pa.extend(rand_prompt(&mut rng, 12));
    let mut pb = prefix;
    pb.extend(rand_prompt(&mut rng, 12));

    let want_a = {
        let mut s = Scheduler::new_sim(cfg(page, 1, 10_000));
        let mut r = Request::new(1, pa.clone(), 16);
        r.budget = 1024;
        r.policy = "full".into();
        s.submit(r);
        s.run_to_completion().unwrap().pop().unwrap().tokens
    };

    let session = Session::new_sim(SchedConfig { prefix_cache: true, ..cfg(page, 2, 10_000) });
    let submit = |p: Vec<u32>| {
        session
            .submit(RequestBuilder::new(p).max_new_tokens(16).budget(1024).policy("full"))
            .unwrap()
    };
    let ha = submit(pa);
    session.step().unwrap(); // A admitted, prefix published
    let hb = submit(pb);
    session.step().unwrap(); // B admitted, maps the 4 shared pages
    let hits = session.with_scheduler(|s| s.prefix_hit_blocks);
    assert!(hits >= 4, "B must map the shared prefix (got {hits} hits)");
    session.step().unwrap();
    assert!(hb.cancel(), "sharer is cancellable mid-decode");
    session.run_until_idle().unwrap();
    let out_a = finished_of(&ha.drain()).unwrap();
    assert_eq!(out_a.tokens, want_a, "survivor decodes on intact shared pages");
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0, "no leak");
}

/// Cancelling a victim parked in the swap pool: the snapshot is dropped,
/// the queue entry purged, and the survivor finishes bit-identically.
#[test]
fn cancel_while_swapped_out_discards_snapshot_and_queue_entry() {
    let page = 4;
    let gen = 24;
    let mut rng = Pcg32::new(17);
    let pa = rand_prompt(&mut rng, 64);
    let pb = rand_prompt(&mut rng, 64);
    let want_a = {
        let mut s = Scheduler::new_sim(cfg(page, 1, 10_000));
        let mut r = Request::new(1, pa.clone(), gen);
        r.budget = 16;
        r.policy = "full".into();
        s.submit(r);
        s.run_to_completion().unwrap().pop().unwrap().tokens
    };

    let session =
        Session::new_sim(SchedConfig { swap_bytes: 16 << 20, ..cfg(page, 2, 36) });
    let submit = |p: Vec<u32>| {
        session
            .submit(RequestBuilder::new(p).max_new_tokens(gen).budget(16).policy("full"))
            .unwrap()
    };
    let ha = submit(pa);
    let hb = submit(pb);
    // step until the younger sequence is parked in the swap pool
    let mut swapped = false;
    for _ in 0..200 {
        session.step().unwrap();
        if hb
            .drain()
            .iter()
            .any(|e| matches!(e, SeqEvent::Preempted { swap: true }))
        {
            swapped = true;
            break;
        }
    }
    assert!(swapped, "36 blocks + swap pool must park the younger victim");
    let parked = session.with_scheduler(|s| s.swap_pool().contains(hb.id().raw()));
    if parked {
        // cancel while the snapshot sits in the pool
        assert_eq!(session.pending(), 1, "victim waits in the queue");
        assert!(hb.cancel());
        assert!(
            session.with_scheduler(|s| !s.swap_pool().contains(hb.id().raw())),
            "cancel must drop the parked snapshot"
        );
        assert_eq!(
            session.with_scheduler(|s| s.swap_pool().used_bytes()),
            0,
            "swap bytes reclaimed"
        );
        assert_eq!(session.pending(), 0, "queue entry purged");
    } else {
        // pool restored it before we looked — cancel mid-decode instead
        assert!(hb.cancel());
    }
    session.run_until_idle().unwrap();
    let out_a = finished_of(&ha.drain()).unwrap();
    assert_eq!(out_a.tokens, want_a, "survivor output bit-identical");
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0);
    assert!(hb.drain().iter().all(|e| !matches!(e, SeqEvent::Finished(_))));
}

/// Deadlines: a running request finishes with `Deadline` carrying what it
/// produced; a queued one expires with zero tokens; no arena leaks.
#[test]
fn deadlines_expire_running_and_queued_requests() {
    let mut rng = Pcg32::new(13);
    // running: 100-token ask, 5-round deadline
    let session = Session::new_sim(cfg(4, 2, 10_000));
    let h = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 16))
                .max_new_tokens(100)
                .deadline_steps(5),
        )
        .unwrap();
    session.run_until_idle().unwrap();
    let out = finished_of(&h.drain()).unwrap();
    assert_eq!(out.finish, FinishReason::Deadline);
    assert!(
        !out.tokens.is_empty() && out.tokens.len() <= 5,
        "deadline keeps the {} produced tokens",
        out.tokens.len()
    );

    // queued: one slot, elder hogs it past the younger's deadline
    let session = Session::new_sim(cfg(4, 1, 10_000));
    let elder = session
        .submit(RequestBuilder::new(rand_prompt(&mut rng, 16)).max_new_tokens(50))
        .unwrap();
    let starved = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 16))
                .max_new_tokens(10)
                .deadline_steps(3),
        )
        .unwrap();
    session.run_until_idle().unwrap();
    let out = finished_of(&starved.drain()).unwrap();
    assert_eq!(out.finish, FinishReason::Deadline);
    assert!(out.tokens.is_empty(), "never admitted: nothing produced");
    let elder_out = finished_of(&elder.drain()).unwrap();
    assert_eq!(elder_out.finish, FinishReason::MaxTokens);
    assert_eq!(elder_out.tokens.len(), 50);
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0);
}

/// SATELLITE: the admission claim estimate is memoized on the queue entry
/// keyed by the prefix-index epoch — gated retries stop recomputing the
/// O(prompt) scorer + hash chain; an index change invalidates exactly
/// once.
#[test]
fn admission_claim_is_memoized_across_gated_retries() {
    let page = 4;
    let mut rng = Pcg32::new(19);
    let session = Session::new_sim(SchedConfig {
        watermark_low: 0.5,  // low mark = 10 of 20 blocks
        watermark_high: 1.0,
        prefix_cache: true,
        ..cfg(page, 2, 20)
    });
    // elder: 8 prompt blocks, holds the arena above the B gate for many
    // rounds (full policy: no evictions, so no mid-run unpublishes)
    let ha = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 32))
                .max_new_tokens(8)
                .budget(1024)
                .policy("full"),
        )
        .unwrap();
    let hb = session
        .submit(
            RequestBuilder::new(rand_prompt(&mut rng, 32))
                .max_new_tokens(4)
                .budget(1024)
                .policy("full"),
        )
        .unwrap();
    session.step().unwrap();
    assert_eq!(session.running(), 1, "B is gated: 8 used + 8 incoming > 10");
    assert_eq!(session.pending(), 1);
    let calls_after_round_1 = session.with_scheduler(|s| s.backend().claim_calls());
    assert_eq!(calls_after_round_1, 2, "one claim each for A and B");
    for _ in 0..5 {
        session.step().unwrap();
    }
    assert_eq!(
        session.with_scheduler(|s| s.backend().claim_calls()),
        2,
        "gated retries must hit the memo, not recompute"
    );
    session.run_until_idle().unwrap();
    // A's retirement unpublished its blocks -> epoch moved -> exactly one
    // recompute when B finally admitted
    assert_eq!(
        session.with_scheduler(|s| s.backend().claim_calls()),
        3,
        "a prefix-index change invalidates the memo exactly once"
    );
    assert!(finished_of(&ha.drain()).is_some());
    let out_b = finished_of(&hb.drain()).unwrap();
    assert_eq!(out_b.finish, FinishReason::MaxTokens);
}

/// Builder stop-token sets terminate generation with `Eos`.
#[test]
fn stop_token_set_stops_generation() {
    let mut rng = Pcg32::new(23);
    let prompt = rand_prompt(&mut rng, 16);
    let session = Session::new_sim(cfg(4, 2, 10_000));
    let probe = session
        .submit(RequestBuilder::new(prompt.clone()).max_new_tokens(10))
        .unwrap();
    session.run_until_idle().unwrap();
    let toks = finished_of(&probe.drain()).unwrap().tokens;
    assert_eq!(toks.len(), 10);
    // pick a stop token whose FIRST occurrence is mid-stream
    let stop_at = (1..10)
        .find(|&i| !toks[..i].contains(&toks[i]))
        .expect("10 greedy tokens cannot all be equal");

    let h = session
        .submit(
            RequestBuilder::new(prompt)
                .max_new_tokens(10)
                .stop_tokens(vec![toks[stop_at], 7777]),
        )
        .unwrap();
    session.run_until_idle().unwrap();
    let out = finished_of(&h.drain()).unwrap();
    assert_eq!(out.finish, FinishReason::Eos);
    assert_eq!(out.tokens, toks[..=stop_at].to_vec(), "stops AT the stop token");
}

/// Submit-time failures surface without a step: zero budget rejects with
/// an error output, unknown policies fail the submit itself.
#[test]
fn submit_time_failures_are_immediate() {
    let session = Session::new_sim(cfg(4, 2, 64));
    assert!(session.submit(RequestBuilder::new(vec![1, 2]).policy("quantum")).is_err());
    assert!(session.submit(RequestBuilder::new(vec![])).is_err(), "empty prompt");
    let h = session
        .submit(RequestBuilder::new(vec![1, 2, 3]).budget(0))
        .unwrap();
    // no step needed: the rejection is routed at submit
    match h.poll() {
        Some(SeqEvent::Finished(o)) => assert_eq!(o.finish, FinishReason::Error),
        other => panic!("want immediate Finished(Error), got {other:?}"),
    }
    assert_eq!(h.state(), HandleState::Finished);
    assert!(session.is_idle());
}
