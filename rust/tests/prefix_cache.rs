//! Refcounted prefix caching end to end on the deterministic sim backend:
//! greedy outputs must be BIT-IDENTICAL with the prefix cache on and off —
//! under contention (forced preemption), under structured eviction of
//! shared prefix pages, and across swap round-trips — while the on-runs
//! report nonzero `prefix_hit_blocks` and a lower physical peak.
//!
//! The sim backend's logits are a pure function of token history and the
//! cached-load serialization is pinned bit-identical to the uncached path
//! (seq_cache property tests), so any output drift here means a sequence
//! observed another sequence's mutation through a shared page — exactly
//! the corruption refcounts + copy-on-write must make impossible.

use std::collections::HashSet;

use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::BlockManager;
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::SimBackend;
use paged_eviction::scheduler::backend::{DecodeBackend, HostSnapshot, Prefilled, Restored};
use paged_eviction::scheduler::{
    FinishReason, Request, RequestOutput, SchedConfig, Scheduler, SwapPool,
};
use paged_eviction::util::rng::Pcg32;

fn cfg(page: usize, conc: usize, arena_blocks: usize, prefix: bool) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        watermark_low: 1.0,
        watermark_high: 1.0,
        swap_bytes: 0,
        prefix_cache: prefix,
        ..SchedConfig::default()
    }
}

fn mk_req(id: u64, prompt: Vec<u32>, gen: usize, budget: usize, policy: &str) -> Request {
    let mut r = Request::new(id, prompt, gen);
    r.budget = budget;
    r.policy = policy.to_string();
    r
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

fn run(cfg: SchedConfig, reqs: &[Request]) -> (Vec<RequestOutput>, Scheduler<SimBackend>) {
    let mut sched = Scheduler::new_sim(cfg);
    for r in reqs {
        sched.submit(r.clone());
    }
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (outs, sched)
}

fn assert_same_tokens(a: &[RequestOutput], b: &[RequestOutput], what: &str) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "req {}: {what}", x.id);
    }
}

/// The acceptance property: a shared-prompt workload (mixed policies,
/// including an unstructured one that forces copy-on-write) produces
/// bit-identical greedy outputs with the prefix cache on and off, hits on
/// the shared blocks, and peaks LOWER physically when sharing is on.
#[test]
fn outputs_bit_identical_with_prefix_cache_on_and_off() {
    let page = 4;
    let mut rng = Pcg32::new(31);
    let prompt = rand_prompt(&mut rng, 64); // 16 full pages of entries
    let reqs = vec![
        mk_req(1, prompt.clone(), 8, 1024, "full"),
        mk_req(2, prompt.clone(), 8, 1024, "full"),
        mk_req(3, prompt.clone(), 8, 1024, "paged"),
        mk_req(4, prompt.clone(), 8, 1024, "streaming"),
        // budget < prompt + generation: these kill tokens every step, so
        // their shared prefix pages must be copied-on-write, never pruned
        // in place (streaming is structured in the paper's taxonomy but
        // drains its oldest page IN PLACE — same CoW obligation)
        mk_req(5, prompt.clone(), 8, 64, "inverse_key_norm"),
        mk_req(6, prompt, 8, 64, "streaming"),
    ];

    let (on, s_on) = run(cfg(page, 8, 10_000, true), &reqs);
    let (off, s_off) = run(cfg(page, 8, 10_000, false), &reqs);

    assert_same_tokens(&on, &off, "prefix cache must not change outputs");
    for o in &on {
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
    }

    // reqs 2..=6 each map all 16 prompt pages from req 1's publication
    assert_eq!(s_on.prefix_hit_blocks, 80, "5 borrowers x 16 shared pages");
    assert_eq!(s_off.prefix_hit_blocks, 0);
    assert!(
        s_on.cow_copies >= 2,
        "both killing policies must copy-on-write their shared pages"
    );
    assert_eq!(s_off.cow_copies, 0);
    let hits: u64 = on.iter().map(|o| o.cache_stats.prefix_hit_blocks).sum();
    assert_eq!(hits, 80, "hits surface per request through CacheStats");
    assert!(on[4].cache_stats.cow_copies > 0, "inverse_key_norm copied");
    assert!(on[5].cache_stats.cow_copies > 0, "streaming copied");

    // sharing peaks lower: 16 shared pages once vs six private copies
    let peak_on = s_on.arena().stats().peak_used;
    let peak_off = s_off.arena().stats().peak_used;
    assert!(
        peak_on < peak_off,
        "shared prefixes must lower the physical peak (on {peak_on} >= off {peak_off})"
    );
    // everything drains: refcounted release leaks nothing
    assert_eq!(s_on.arena().used(), 0);
    assert_eq!(s_off.arena().used(), 0);
}

/// Two sequences share a 32-token prefix; one (paged) structurally evicts
/// shared pages mid-decode, the other (full) outgrows a 24-block arena
/// and gets preempted. Both must finish with outputs bit-identical to an
/// uncontended run — eviction-from-running of one sharer can never
/// corrupt the other's view.
#[test]
fn shared_prefix_survives_preemption_and_shared_page_eviction() {
    let page = 4;
    let mut rng = Pcg32::new(77);
    let shared = rand_prompt(&mut rng, 32); // 8 full pages
    let mut pa = shared.clone();
    pa.extend(rand_prompt(&mut rng, 16));
    let mut pb = shared;
    pb.extend(rand_prompt(&mut rng, 16));
    // req 1: paged, budget == prompt, so decode eviction drops one page
    // (often a shared one) every time a new page fills
    // req 2: full, growing to 12 prefill + 7 decode blocks — the
    // designated preemption victim, sized to finish ALONE in the small
    // arena (19 <= 20) while the joint demand cannot fit (>= 21 by round
    // 13 in every eviction trajectory)
    let reqs = vec![
        mk_req(1, pa, 16, 48, "paged"),
        mk_req(2, pb, 28, 1024, "full"),
    ];

    let (uncontended, s0) = run(cfg(page, 2, 10_000, true), &reqs);
    assert_eq!(s0.preemptions, 0, "ample arena must not preempt");
    assert!(s0.prefix_hit_blocks >= 8, "the shared prefix must hit");

    // prefix caching alone must not change tokens
    let (plain, _) = run(cfg(page, 2, 10_000, false), &reqs);
    assert_same_tokens(&uncontended, &plain, "prefix cache changed outputs");

    // recompute leg: joint demand crosses 20 while both run
    let (contended, s1) = run(cfg(page, 2, 20, true), &reqs);
    assert!(s1.preemptions >= 1, "a 20-block arena cannot absorb the growth");
    assert!(s1.prefix_hit_blocks >= 8);
    assert_same_tokens(&uncontended, &contended, "preemption lost or corrupted work");
    assert!(contended[1].preemptions >= 1, "the youngest (full) was the victim");
    assert_eq!(contended[0].preemptions, 0);

    // swap leg: the victim's snapshot holds SHARED pages; restore comes
    // back on private copies, still bit-identical
    let (swapped, s2) = run(
        SchedConfig { swap_bytes: 16 << 20, ..cfg(page, 2, 20, true) },
        &reqs,
    );
    assert!(s2.preemptions >= 1);
    assert!(s2.swap_outs >= 1, "the victim must park in the pool");
    assert_same_tokens(&uncontended, &swapped, "swap round-trip drifted");

    for s in [&s1, &s2] {
        assert_eq!(s.arena().used(), 0, "refcounted release drains the arena");
        assert!(s.arena().stats().peak_used <= 20, "capacity stays a hard bound");
    }
}

/// Regression: StreamingLLM's sliding window kills tokens IN PLACE, so it
/// must be unshared during reservation like the unstructured policies —
/// when the arena is too dry for the copy-on-write, the scheduler must
/// PREEMPT the streaming sequence (and replay it losslessly), not panic
/// inside the decode-path CoW fallback.
#[test]
fn streaming_window_over_shared_prefix_preempts_instead_of_panicking() {
    let page = 4;
    let mut rng = Pcg32::new(99);
    let prompt = rand_prompt(&mut rng, 32); // 8 full pages
    let reqs = vec![
        // publisher: keeps the pages shared and the arena busy
        mk_req(1, prompt.clone(), 8, 1024, "full"),
        // budget == prompt: the window starts killing on the first decode
        // step, while all 8 of its prompt pages are still shared
        mk_req(2, prompt, 8, 32, "streaming"),
    ];
    let (uncontended, s0) = run(cfg(page, 2, 10_000, true), &reqs);
    assert_eq!(s0.preemptions, 0);

    // 12 blocks: req1 holds 9 after its first reservation, so req2's
    // 8-page unshare cannot fit — prepare_round must report ArenaDry and
    // the scheduler must preempt req2 (pre-fix, the lazy decode-path CoW
    // panicked here once the arena ran dry mid-kill)
    let (outs, sched) = run(cfg(page, 2, 12, true), &reqs);
    assert!(sched.preemptions >= 1, "the dry unshare must preempt");
    assert!(sched.prefix_hit_blocks >= 8);
    assert_same_tokens(&uncontended, &outs, "streaming victim lost work");
    assert!(outs[1].preemptions >= 1, "the streaming sequence was the victim");
    for o in &outs {
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
    }
    assert_eq!(sched.arena().used(), 0);
}

/// Backend-level survivor integrity: drop one sharer mid-decode (the
/// preemption primitive) and the survivor must keep decoding exactly like
/// a sequence that never shared anything.
#[test]
fn dropping_a_sharer_never_disturbs_the_survivor() {
    let page = 4;
    let mut rng = Pcg32::new(5);
    let prompt = rand_prompt(&mut rng, 64);

    // solo reference: same prompt, nothing ever shared
    let solo_tokens = {
        let arena = BlockManager::new(1000);
        let mut be = SimBackend::new(page);
        be.set_prefix_cache(true);
        let Prefilled::Ready { mut seq, logits } = be
            .prefill(&arena, &prompt, 1024, make_policy("full").unwrap())
            .unwrap()
        else {
            panic!("solo prefill OOM")
        };
        let mut tok = argmax(&logits);
        let mut out = Vec::new();
        for _ in 0..12 {
            out.push(tok);
            while !seq.cache.ensure_block() {
                be.grow_bucket(&mut seq).unwrap();
            }
            let mut b = [(&mut seq, tok)];
            tok = argmax(&be.decode_batch(&mut b).pop().unwrap().unwrap());
        }
        out
    };

    let arena = BlockManager::new(1000);
    let mut be = SimBackend::new(page);
    be.set_prefix_cache(true);
    let Prefilled::Ready { seq: mut a, logits } = be
        .prefill(&arena, &prompt, 1024, make_policy("full").unwrap())
        .unwrap()
    else {
        panic!("prefill OOM")
    };
    let mut tok_a = argmax(&logits);
    let Prefilled::Ready { seq: b, .. } = be
        .prefill(&arena, &prompt, 1024, make_policy("full").unwrap())
        .unwrap()
    else {
        panic!("prefill OOM")
    };
    assert_eq!(b.cache.stats.prefix_hit_blocks, 16, "the twin maps every page");
    assert_eq!(arena.used(), 16, "two prompts, one set of physical pages");

    let mut sharer = Some(b);
    let mut out = Vec::new();
    for step in 0..12 {
        out.push(tok_a);
        while !a.cache.ensure_block() {
            be.grow_bucket(&mut a).unwrap();
        }
        let mut batch = [(&mut a, tok_a)];
        tok_a = argmax(&be.decode_batch(&mut batch).pop().unwrap().unwrap());
        if step == 5 {
            // preemption stand-in: the co-holder vanishes mid-decode,
            // releasing its shared claims by refcount
            sharer = None;
        }
    }
    drop(sharer);
    assert_eq!(out, solo_tokens, "survivor drifted after its sharer dropped");
    a.cache.check_invariants().unwrap();
    drop(a);
    assert_eq!(arena.used(), 0, "everything released by refcount");
}

/// Satellite: a parked swap snapshot pins NO arena blocks — snapshots are
/// pure host copies — so LRU-dropping (or discarding) one can never free
/// a page another live sequence still shares; and restoring one claims
/// fresh PRIVATE pages, never a live sharer's.
#[test]
fn swap_pool_drops_and_restores_never_touch_shared_pages() {
    let page = 4;
    let mut rng = Pcg32::new(13);
    let prompt = rand_prompt(&mut rng, 64);
    let arena = BlockManager::new(64);
    let mut be = SimBackend::new(page);
    be.set_prefix_cache(true);
    let Prefilled::Ready { seq: a, .. } = be
        .prefill(&arena, &prompt, 1024, make_policy("full").unwrap())
        .unwrap()
    else {
        panic!("prefill OOM")
    };
    let Prefilled::Ready { seq: b, .. } = be
        .prefill(&arena, &prompt, 1024, make_policy("full").unwrap())
        .unwrap()
    else {
        panic!("prefill OOM")
    };
    assert_eq!(b.cache.stats.prefix_hit_blocks, 16);
    let used = arena.used();
    assert_eq!(used, 16);

    // park b's snapshot, then LRU-drop it by overfilling a tight pool
    let snap_b = be.snapshot(&b).expect("sim backend snapshots");
    let bytes = snap_b.host_bytes();
    let mut pool = SwapPool::new(bytes + bytes / 2);
    assert!(pool.insert(2, snap_b));
    assert!(pool.insert(1, be.snapshot(&a).expect("snapshot a")));
    assert_eq!(pool.dropped(), 1, "the tight cap LRU-dropped b's snapshot");
    assert_eq!(arena.used(), used, "dropping a parked snapshot frees NOTHING");
    a.cache.check_invariants().unwrap();
    b.cache.check_invariants().unwrap();

    // discarding the survivor's entry is equally inert
    pool.discard(1);
    assert_eq!(arena.used(), used);

    // a fresh snapshot of b restores onto private pages disjoint from a's
    let snap = be.snapshot(&b).expect("snapshot b");
    drop(b); // the victim itself is gone (preempted); a keeps the pages
    assert_eq!(arena.used(), used, "a's claims keep every shared page alive");
    let Restored::Ready(r) = be.restore(&arena, &snap).unwrap() else {
        panic!("restore OOM")
    };
    assert_eq!(arena.used(), used + 16, "restore claims fresh private pages");
    let a_pages: HashSet<usize> = a.cache.blocks().iter().map(|bl| bl.arena_slot).collect();
    assert!(
        r.cache.blocks().iter().all(|bl| !a_pages.contains(&bl.arena_slot)),
        "a restored snapshot must never alias a live sharer's pages"
    );
    r.cache.check_invariants().unwrap();
}
