//! Property tests for the shared `BlockManager` arena under concurrent
//! multi-tenant use: random alloc / evict / kill / grow / drop traffic
//! across N `SeqCache` tenants must never double-free, never exceed
//! capacity, and keep per-tenant ownership exactly consistent with the
//! arena's O(1) global accounting.

use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::{BlockAlloc, BlockManager, SeqCache};
use paged_eviction::util::propcheck;
use paged_eviction::util::rng::Pcg32;

fn sc(rng: &mut Pcg32) -> [f32; 3] {
    [rng.f32(), rng.f32(), rng.f32()]
}

#[test]
fn property_multi_tenant_arena_stays_consistent() {
    propcheck::quick("arena-multi-tenant", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[2usize, 4, 8]);
        let capacity = 6 + rng.usize_below(26);
        let arena = BlockManager::new(capacity);
        let n_caches = 2 + rng.usize_below(4);
        let mut caches: Vec<Option<SeqCache>> = (0..n_caches)
            .map(|_| Some(SeqCache::new_shared(bs, capacity, &arena)))
            .collect();
        // seed each tenant with a small prefill if the arena allows
        for slot in caches.iter_mut() {
            let c = slot.as_mut().unwrap();
            let want = 1 + rng.usize_below(2 * bs);
            let toks: Vec<(u32, [f32; 3])> =
                (0..want as u32).map(|i| (i, [rng.f32(); 3])).collect();
            if c.try_load_prefill(&toks, want as u32).is_err() {
                *slot = None; // arena too small for this tenant — drop it
            }
        }

        let check_all = |arena: &BlockManager,
                         caches: &[Option<SeqCache>]|
         -> Result<(), String> {
            let held: usize = caches
                .iter()
                .flatten()
                .map(|c| c.n_blocks())
                .sum();
            let stats = arena.stats();
            if stats.used != held {
                return Err(format!("arena used {} != tenants hold {held}", stats.used));
            }
            if stats.used + arena.free_count() != stats.capacity {
                return Err("used + free != capacity".into());
            }
            if stats.peak_used < stats.used || stats.peak_used > stats.capacity {
                return Err(format!(
                    "peak {} outside [used {}, capacity {}]",
                    stats.peak_used, stats.used, stats.capacity
                ));
            }
            for c in caches.iter().flatten() {
                c.check_invariants()?;
                if arena.owned_by(c.seq_id()) != c.n_blocks() {
                    return Err("per-seq ownership drifted".into());
                }
            }
            Ok(())
        };

        for _ in 0..150 {
            let pick = rng.usize_below(caches.len());
            match rng.below(10) {
                // append a token (allocating a block when needed)
                0..=5 => {
                    let outcome = caches[pick].as_mut().map(|c| c.try_ensure_block());
                    match outcome {
                        Some(BlockAlloc::Ready) => {
                            let s = sc(rng);
                            caches[pick].as_mut().unwrap().append(s);
                        }
                        Some(BlockAlloc::BucketFull) => {
                            let c = caches[pick].as_mut().unwrap();
                            let nb = c.capacity_blocks() + 2;
                            c.grow(nb); // bucket only; arena unchanged
                        }
                        Some(BlockAlloc::ArenaDry) => {
                            if arena.free_count() != 0 {
                                return Err("ArenaDry with free blocks".into());
                            }
                            if rng.below(2) == 0 {
                                // preemption stand-in: drop a tenant
                                let victim = rng.usize_below(caches.len());
                                let before = arena.used();
                                let freed = caches[victim]
                                    .as_ref()
                                    .map(|c| c.n_blocks())
                                    .unwrap_or(0);
                                caches[victim] = None;
                                if arena.used() != before - freed {
                                    return Err("drop freed wrong count".into());
                                }
                            }
                        }
                        None => {}
                    }
                }
                // structured eviction
                6..=7 => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.n_blocks() > 1 {
                            let idx = rng.usize_below(c.n_blocks() - 1);
                            c.evict_block(idx);
                        }
                    }
                }
                // unstructured kill via a real policy decision
                _ => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.live_tokens() > 2 {
                            let p = make_policy("inverse_key_norm").unwrap();
                            if let paged_eviction::eviction::Decision::KillTokens(ts) =
                                p.post_append(c, c.live_tokens() - 1)
                            {
                                for (bi, off) in ts {
                                    c.kill_token(bi, off);
                                }
                            }
                        }
                    }
                }
            }
            check_all(&arena, &caches)?;
        }

        // drop everything: the arena must drain to empty
        for slot in caches.iter_mut() {
            *slot = None;
        }
        if arena.used() != 0 {
            return Err(format!("leak: {} blocks after dropping all tenants", arena.used()));
        }
        if arena.free_count() != arena.capacity() {
            return Err("free list incomplete after drain".into());
        }
        Ok(())
    });
}

#[test]
fn arena_capacity_is_a_hard_bound() {
    let arena = BlockManager::new(5);
    let mut a = SeqCache::new_shared(2, 16, &arena);
    let mut b = SeqCache::new_shared(2, 16, &arena);
    let mut allocated = 0;
    loop {
        let c = if allocated % 2 == 0 { &mut a } else { &mut b };
        match c.try_ensure_block() {
            BlockAlloc::Ready => {
                c.append([0.5; 3]);
                c.append([0.5; 3]); // fill the page (bs = 2)
                allocated += 1;
            }
            BlockAlloc::ArenaDry => break,
            BlockAlloc::BucketFull => unreachable!("bucket 16 > capacity 5"),
        }
    }
    assert_eq!(allocated, 5, "exactly capacity blocks were ever handed out");
    assert_eq!(arena.used(), 5);
    assert_eq!(arena.stats().peak_used, 5);
}
