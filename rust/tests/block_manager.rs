//! Property tests for the shared `BlockManager` arena under concurrent
//! multi-tenant use: random alloc / evict / kill / grow / drop traffic
//! across N `SeqCache` tenants must never double-free, never exceed
//! capacity, and keep per-tenant ownership exactly consistent with the
//! arena's O(1) global accounting.

use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::{BlockAlloc, BlockManager, SeqCache};
use paged_eviction::util::propcheck;
use paged_eviction::util::rng::Pcg32;

fn sc(rng: &mut Pcg32) -> [f32; 3] {
    [rng.f32(), rng.f32(), rng.f32()]
}

#[test]
fn property_multi_tenant_arena_stays_consistent() {
    propcheck::quick("arena-multi-tenant", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[2usize, 4, 8]);
        let capacity = 6 + rng.usize_below(26);
        let arena = BlockManager::new(capacity);
        let n_caches = 2 + rng.usize_below(4);
        let mut caches: Vec<Option<SeqCache>> = (0..n_caches)
            .map(|_| Some(SeqCache::new_shared(bs, capacity, &arena)))
            .collect();
        // seed each tenant with a small prefill if the arena allows
        for slot in caches.iter_mut() {
            let c = slot.as_mut().unwrap();
            let want = 1 + rng.usize_below(2 * bs);
            let toks: Vec<(u32, [f32; 3])> =
                (0..want as u32).map(|i| (i, [rng.f32(); 3])).collect();
            if c.try_load_prefill(&toks, want as u32).is_err() {
                *slot = None; // arena too small for this tenant — drop it
            }
        }

        let check_all = |arena: &BlockManager,
                         caches: &[Option<SeqCache>]|
         -> Result<(), String> {
            let held: usize = caches
                .iter()
                .flatten()
                .map(|c| c.n_blocks())
                .sum();
            let stats = arena.stats();
            if stats.used != held {
                return Err(format!("arena used {} != tenants hold {held}", stats.used));
            }
            if stats.used + arena.free_count() != stats.capacity {
                return Err("used + free != capacity".into());
            }
            if stats.peak_used < stats.used || stats.peak_used > stats.capacity {
                return Err(format!(
                    "peak {} outside [used {}, capacity {}]",
                    stats.peak_used, stats.used, stats.capacity
                ));
            }
            for c in caches.iter().flatten() {
                c.check_invariants()?;
                if arena.owned_by(c.seq_id()) != c.n_blocks() {
                    return Err("per-seq ownership drifted".into());
                }
            }
            Ok(())
        };

        for _ in 0..150 {
            let pick = rng.usize_below(caches.len());
            match rng.below(10) {
                // append a token (allocating a block when needed)
                0..=5 => {
                    let outcome = caches[pick].as_mut().map(|c| c.try_ensure_block());
                    match outcome {
                        Some(BlockAlloc::Ready) => {
                            let s = sc(rng);
                            caches[pick].as_mut().unwrap().append(s);
                        }
                        Some(BlockAlloc::BucketFull) => {
                            let c = caches[pick].as_mut().unwrap();
                            let nb = c.capacity_blocks() + 2;
                            c.grow(nb); // bucket only; arena unchanged
                        }
                        Some(BlockAlloc::ArenaDry) => {
                            if arena.free_count() != 0 {
                                return Err("ArenaDry with free blocks".into());
                            }
                            if rng.below(2) == 0 {
                                // preemption stand-in: drop a tenant
                                let victim = rng.usize_below(caches.len());
                                let before = arena.used();
                                let freed = caches[victim]
                                    .as_ref()
                                    .map(|c| c.n_blocks())
                                    .unwrap_or(0);
                                caches[victim] = None;
                                if arena.used() != before - freed {
                                    return Err("drop freed wrong count".into());
                                }
                            }
                        }
                        None => {}
                    }
                }
                // structured eviction
                6..=7 => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.n_blocks() > 1 {
                            let idx = rng.usize_below(c.n_blocks() - 1);
                            c.evict_block(idx);
                        }
                    }
                }
                // unstructured kill via a real policy decision
                _ => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.live_tokens() > 2 {
                            let p = make_policy("inverse_key_norm").unwrap();
                            if let paged_eviction::eviction::Decision::KillTokens(ts) =
                                p.post_append(c, c.live_tokens() - 1)
                            {
                                for (bi, off) in ts {
                                    c.kill_token(bi, off);
                                }
                            }
                        }
                    }
                }
            }
            check_all(&arena, &caches)?;
        }

        // drop everything: the arena must drain to empty
        for slot in caches.iter_mut() {
            *slot = None;
        }
        if arena.used() != 0 {
            return Err(format!("leak: {} blocks after dropping all tenants", arena.used()));
        }
        if arena.free_count() != arena.capacity() {
            return Err("free list incomplete after drain".into());
        }
        Ok(())
    });
}

/// Satellite: N tenants acquiring/releasing refcounted shared slots
/// against a mirror model — no double free, a slot frees (and leaves the
/// prefix index) only at refcount 0, `used()` counts a shared slot once,
/// and per-tenant claim accounting never drifts.
#[test]
fn property_refcounted_sharing_stays_consistent() {
    use std::collections::HashSet;
    propcheck::quick("arena-refcount-sharing", |rng: &mut Pcg32| {
        let capacity = 4 + rng.usize_below(12);
        let arena = BlockManager::new(capacity);
        let n = 2 + rng.usize_below(4);
        let ids: Vec<_> = (0..n).map(|_| arena.register()).collect();
        // mirror model: holds[t] = slots tenant t claims (each at most once)
        let mut holds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut published: Vec<(u64, usize)> = Vec::new();
        let mut next_hash: u64 = 1;
        for _ in 0..200 {
            let t = rng.usize_below(n);
            match rng.below(4) {
                // private alloc, sometimes published into the index
                0 => {
                    if let Some(p) = arena.alloc(ids[t]) {
                        holds[t].push(p);
                        if rng.below(2) == 0 {
                            let h = next_hash;
                            next_hash += 1;
                            if arena.publish(ids[t], p, h) {
                                published.push((h, p));
                            }
                        }
                    } else if arena.free_count() != 0 {
                        return Err("alloc failed with free slots".into());
                    }
                }
                // shared acquire through the index
                1 => {
                    if !published.is_empty() {
                        let (h, p) = published[rng.usize_below(published.len())];
                        let already = holds[t].contains(&p);
                        match arena.acquire_shared(ids[t], h) {
                            Some(got) => {
                                if got != p {
                                    return Err("hash resolved to the wrong slot".into());
                                }
                                if already {
                                    return Err("double-acquire of a held slot".into());
                                }
                                holds[t].push(p);
                            }
                            None if already => {} // correct: at most one claim per slot
                            None => return Err(format!("miss on published hash {h}")),
                        }
                    }
                }
                // release one claim
                2 => {
                    if !holds[t].is_empty() {
                        let i = rng.usize_below(holds[t].len());
                        let p = holds[t].swap_remove(i);
                        arena.release(ids[t], p);
                        if holds.iter().all(|hs| !hs.contains(&p)) {
                            published.retain(|&(_, s)| s != p);
                            if arena.refcount(p) != 0 {
                                return Err("slot free but refcount > 0".into());
                            }
                        }
                    }
                }
                // tenant evicted-from-running: release everything it holds
                _ => {
                    while let Some(p) = holds[t].pop() {
                        arena.release(ids[t], p);
                        if holds.iter().all(|hs| !hs.contains(&p)) {
                            published.retain(|&(_, s)| s != p);
                        }
                    }
                }
            }
            // arena vs mirror: global and per-slot accounting
            let mut live: HashSet<usize> = HashSet::new();
            for hs in &holds {
                live.extend(hs.iter().copied());
            }
            if arena.used() != live.len() {
                return Err(format!(
                    "used {} != distinct held {} (shared slots must count once)",
                    arena.used(),
                    live.len()
                ));
            }
            if arena.used() + arena.free_count() != arena.capacity() {
                return Err("used + free != capacity".into());
            }
            for &p in &live {
                let rc = holds.iter().filter(|hs| hs.contains(&p)).count();
                if arena.refcount(p) != rc {
                    return Err(format!("refcount({p}) {} != model {rc}", arena.refcount(p)));
                }
            }
            for (t2, hs) in holds.iter().enumerate() {
                if arena.owned_by(ids[t2]) != hs.len() {
                    return Err("per-tenant claim count drifted".into());
                }
            }
        }
        // full drain: nothing may leak, free only at refcount 0 throughout
        for (t, hs) in holds.iter_mut().enumerate() {
            while let Some(p) = hs.pop() {
                arena.release(ids[t], p);
            }
        }
        if arena.used() != 0 {
            return Err(format!("leak: {} slots after full drain", arena.used()));
        }
        Ok(())
    });
}

/// Satellite: copy-on-write never aliases a writer — every borrower that
/// unshares a page lands on a slot distinct from the shared original and
/// from every other writer's copy.
#[test]
fn cow_never_aliases_a_writer() {
    use std::collections::HashSet;
    let arena = BlockManager::new(64);
    let entries: Vec<(u32, [f32; 3])> = (0..8u32).map(|i| (i, [0.5; 3])).collect();
    let keys: Vec<u64> = (0..8u64).map(|i| i.wrapping_mul(31) ^ 0xabc).collect();
    let mut publisher = SeqCache::new_shared(4, 4, &arena);
    publisher.try_load_prefill_cached(&entries, &keys, 8).unwrap();
    let shared0 = publisher.blocks()[0].arena_slot;
    let mut writers: Vec<SeqCache> = (0..4)
        .map(|_| {
            let mut c = SeqCache::new_shared(4, 4, &arena);
            assert_eq!(c.try_load_prefill_cached(&entries, &keys, 8), Ok(2));
            c
        })
        .collect();
    assert_eq!(arena.refcount(shared0), 5, "publisher + 4 borrowers");
    let mut seen = HashSet::from([shared0]);
    for w in writers.iter_mut() {
        assert_eq!(w.make_private(0), Ok(true), "shared page must be copied");
        let fresh = w.blocks()[0].arena_slot;
        assert!(seen.insert(fresh), "CoW aliased another writer's page");
        w.check_invariants().unwrap();
    }
    assert_eq!(arena.refcount(shared0), 1, "only the publisher remains");
    publisher.check_invariants().unwrap();
}

#[test]
fn arena_capacity_is_a_hard_bound() {
    let arena = BlockManager::new(5);
    let mut a = SeqCache::new_shared(2, 16, &arena);
    let mut b = SeqCache::new_shared(2, 16, &arena);
    let mut allocated = 0;
    loop {
        let c = if allocated % 2 == 0 { &mut a } else { &mut b };
        match c.try_ensure_block() {
            BlockAlloc::Ready => {
                c.append([0.5; 3]);
                c.append([0.5; 3]); // fill the page (bs = 2)
                allocated += 1;
            }
            BlockAlloc::ArenaDry => break,
            BlockAlloc::BucketFull => unreachable!("bucket 16 > capacity 5"),
        }
    }
    assert_eq!(allocated, 5, "exactly capacity blocks were ever handed out");
    assert_eq!(arena.used(), 5);
    assert_eq!(arena.stats().peak_used, 5);
}
