//! Property tests for the shared `BlockManager` arena under concurrent
//! multi-tenant use: random alloc / evict / kill / grow / drop traffic
//! across N `SeqCache` tenants must never double-free, never exceed
//! capacity, and keep per-tenant ownership exactly consistent with the
//! arena's O(1) global accounting.

use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::{BlockAlloc, BlockManager, SeqCache};
use paged_eviction::util::propcheck;
use paged_eviction::util::rng::Pcg32;

fn sc(rng: &mut Pcg32) -> [f32; 3] {
    [rng.f32(), rng.f32(), rng.f32()]
}

#[test]
fn property_multi_tenant_arena_stays_consistent() {
    propcheck::quick("arena-multi-tenant", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[2usize, 4, 8]);
        let capacity = 6 + rng.usize_below(26);
        let arena = BlockManager::new(capacity);
        let n_caches = 2 + rng.usize_below(4);
        let mut caches: Vec<Option<SeqCache>> = (0..n_caches)
            .map(|_| Some(SeqCache::new_shared(bs, capacity, &arena)))
            .collect();
        // seed each tenant with a small prefill if the arena allows
        for slot in caches.iter_mut() {
            let c = slot.as_mut().unwrap();
            let want = 1 + rng.usize_below(2 * bs);
            let toks: Vec<(u32, [f32; 3])> =
                (0..want as u32).map(|i| (i, [rng.f32(); 3])).collect();
            if c.try_load_prefill(&toks, want as u32).is_err() {
                *slot = None; // arena too small for this tenant — drop it
            }
        }

        let check_all = |arena: &BlockManager,
                         caches: &[Option<SeqCache>]|
         -> Result<(), String> {
            let held: usize = caches
                .iter()
                .flatten()
                .map(|c| c.n_blocks())
                .sum();
            let stats = arena.stats();
            if stats.used != held {
                return Err(format!("arena used {} != tenants hold {held}", stats.used));
            }
            if stats.used + arena.free_count() != stats.capacity {
                return Err("used + free != capacity".into());
            }
            if stats.peak_used < stats.used || stats.peak_used > stats.capacity {
                return Err(format!(
                    "peak {} outside [used {}, capacity {}]",
                    stats.peak_used, stats.used, stats.capacity
                ));
            }
            for c in caches.iter().flatten() {
                c.check_invariants()?;
                if arena.owned_by(c.seq_id()) != c.n_blocks() {
                    return Err("per-seq ownership drifted".into());
                }
            }
            Ok(())
        };

        for _ in 0..150 {
            let pick = rng.usize_below(caches.len());
            match rng.below(10) {
                // append a token (allocating a block when needed)
                0..=5 => {
                    let outcome = caches[pick].as_mut().map(|c| c.try_ensure_block());
                    match outcome {
                        Some(BlockAlloc::Ready) => {
                            let s = sc(rng);
                            caches[pick].as_mut().unwrap().append(s);
                        }
                        Some(BlockAlloc::BucketFull) => {
                            let c = caches[pick].as_mut().unwrap();
                            let nb = c.capacity_blocks() + 2;
                            c.grow(nb); // bucket only; arena unchanged
                        }
                        Some(BlockAlloc::ArenaDry) => {
                            if arena.free_count() != 0 {
                                return Err("ArenaDry with free blocks".into());
                            }
                            if rng.below(2) == 0 {
                                // preemption stand-in: drop a tenant
                                let victim = rng.usize_below(caches.len());
                                let before = arena.used();
                                let freed = caches[victim]
                                    .as_ref()
                                    .map(|c| c.n_blocks())
                                    .unwrap_or(0);
                                caches[victim] = None;
                                if arena.used() != before - freed {
                                    return Err("drop freed wrong count".into());
                                }
                            }
                        }
                        None => {}
                    }
                }
                // structured eviction
                6..=7 => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.n_blocks() > 1 {
                            let idx = rng.usize_below(c.n_blocks() - 1);
                            c.evict_block(idx);
                        }
                    }
                }
                // unstructured kill via a real policy decision
                _ => {
                    if let Some(c) = caches[pick].as_mut() {
                        if c.live_tokens() > 2 {
                            let p = make_policy("inverse_key_norm").unwrap();
                            if let paged_eviction::eviction::Decision::KillTokens(ts) =
                                p.post_append(c, c.live_tokens() - 1)
                            {
                                for (bi, off) in ts {
                                    c.kill_token(bi, off);
                                }
                            }
                        }
                    }
                }
            }
            check_all(&arena, &caches)?;
        }

        // drop everything: the arena must drain to empty
        for slot in caches.iter_mut() {
            *slot = None;
        }
        if arena.used() != 0 {
            return Err(format!("leak: {} blocks after dropping all tenants", arena.used()));
        }
        if arena.free_count() != arena.capacity() {
            return Err("free list incomplete after drain".into());
        }
        Ok(())
    });
}

/// Satellite: N tenants acquiring/releasing refcounted shared slots
/// against a mirror model — no double free, a slot frees (and leaves the
/// prefix index) only at refcount 0, `used()` counts a shared slot once,
/// and per-tenant claim accounting never drifts.
#[test]
fn property_refcounted_sharing_stays_consistent() {
    use std::collections::HashSet;
    propcheck::quick("arena-refcount-sharing", |rng: &mut Pcg32| {
        let capacity = 4 + rng.usize_below(12);
        let arena = BlockManager::new(capacity);
        let n = 2 + rng.usize_below(4);
        let ids: Vec<_> = (0..n).map(|_| arena.register()).collect();
        // mirror model: holds[t] = slots tenant t claims (each at most once)
        let mut holds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut published: Vec<(u64, usize)> = Vec::new();
        let mut next_hash: u64 = 1;
        for _ in 0..200 {
            let t = rng.usize_below(n);
            match rng.below(4) {
                // private alloc, sometimes published into the index
                0 => {
                    if let Some(p) = arena.alloc(ids[t]) {
                        holds[t].push(p);
                        if rng.below(2) == 0 {
                            let h = next_hash;
                            next_hash += 1;
                            if arena.publish(ids[t], p, h) {
                                published.push((h, p));
                            }
                        }
                    } else if arena.free_count() != 0 {
                        return Err("alloc failed with free slots".into());
                    }
                }
                // shared acquire through the index
                1 => {
                    if !published.is_empty() {
                        let (h, p) = published[rng.usize_below(published.len())];
                        let already = holds[t].contains(&p);
                        match arena.acquire_shared(ids[t], h) {
                            Some(got) => {
                                if got != p {
                                    return Err("hash resolved to the wrong slot".into());
                                }
                                if already {
                                    return Err("double-acquire of a held slot".into());
                                }
                                holds[t].push(p);
                            }
                            None if already => {} // correct: at most one claim per slot
                            None => return Err(format!("miss on published hash {h}")),
                        }
                    }
                }
                // release one claim
                2 => {
                    if !holds[t].is_empty() {
                        let i = rng.usize_below(holds[t].len());
                        let p = holds[t].swap_remove(i);
                        arena.release(ids[t], p);
                        if holds.iter().all(|hs| !hs.contains(&p)) {
                            published.retain(|&(_, s)| s != p);
                            if arena.refcount(p) != 0 {
                                return Err("slot free but refcount > 0".into());
                            }
                        }
                    }
                }
                // tenant evicted-from-running: release everything it holds
                _ => {
                    while let Some(p) = holds[t].pop() {
                        arena.release(ids[t], p);
                        if holds.iter().all(|hs| !hs.contains(&p)) {
                            published.retain(|&(_, s)| s != p);
                        }
                    }
                }
            }
            // arena vs mirror: global and per-slot accounting
            let mut live: HashSet<usize> = HashSet::new();
            for hs in &holds {
                live.extend(hs.iter().copied());
            }
            if arena.used() != live.len() {
                return Err(format!(
                    "used {} != distinct held {} (shared slots must count once)",
                    arena.used(),
                    live.len()
                ));
            }
            if arena.used() + arena.free_count() != arena.capacity() {
                return Err("used + free != capacity".into());
            }
            for &p in &live {
                let rc = holds.iter().filter(|hs| hs.contains(&p)).count();
                if arena.refcount(p) != rc {
                    return Err(format!("refcount({p}) {} != model {rc}", arena.refcount(p)));
                }
            }
            for (t2, hs) in holds.iter().enumerate() {
                if arena.owned_by(ids[t2]) != hs.len() {
                    return Err("per-tenant claim count drifted".into());
                }
            }
        }
        // full drain: nothing may leak, free only at refcount 0 throughout
        for (t, hs) in holds.iter_mut().enumerate() {
            while let Some(p) = hs.pop() {
                arena.release(ids[t], p);
            }
        }
        if arena.used() != 0 {
            return Err(format!("leak: {} slots after full drain", arena.used()));
        }
        Ok(())
    });
}

/// Satellite: copy-on-write never aliases a writer — every borrower that
/// unshares a page lands on a slot distinct from the shared original and
/// from every other writer's copy.
#[test]
fn cow_never_aliases_a_writer() {
    use std::collections::HashSet;
    let arena = BlockManager::new(64);
    let entries: Vec<(u32, [f32; 3])> = (0..8u32).map(|i| (i, [0.5; 3])).collect();
    let keys: Vec<u64> = (0..8u64).map(|i| i.wrapping_mul(31) ^ 0xabc).collect();
    let mut publisher = SeqCache::new_shared(4, 4, &arena);
    publisher.try_load_prefill_cached(&entries, &keys, 8).unwrap();
    let shared0 = publisher.blocks()[0].arena_slot;
    let mut writers: Vec<SeqCache> = (0..4)
        .map(|_| {
            let mut c = SeqCache::new_shared(4, 4, &arena);
            assert_eq!(c.try_load_prefill_cached(&entries, &keys, 8), Ok(2));
            c
        })
        .collect();
    assert_eq!(arena.refcount(shared0), 5, "publisher + 4 borrowers");
    let mut seen = HashSet::from([shared0]);
    for w in writers.iter_mut() {
        assert_eq!(w.make_private(0), Ok(true), "shared page must be copied");
        let fresh = w.blocks()[0].arena_slot;
        assert!(seen.insert(fresh), "CoW aliased another writer's page");
        w.check_invariants().unwrap();
    }
    assert_eq!(arena.refcount(shared0), 1, "only the publisher remains");
    publisher.check_invariants().unwrap();
}

/// Tentpole (PR 9): the batched arena APIs (`alloc_many`,
/// `release_many`, `acquire_shared_run`, `publish_many`) must be
/// OBSERVATIONALLY IDENTICAL to the per-block loops they replaced —
/// same slots in the same order, same failure semantics, same
/// accounting, same watermark verdicts. Twin arenas fed the same random
/// traffic, one through each convention, must never diverge.
#[test]
fn property_batch_ops_mirror_per_block_loops() {
    propcheck::quick("arena-batch-mirror", |rng: &mut Pcg32| {
        let capacity = 6 + rng.usize_below(20);
        let a = BlockManager::new(capacity); // batched calls
        let b = BlockManager::new(capacity); // per-block loops
        a.set_watermarks(0.5, 0.8);
        b.set_watermarks(0.5, 0.8);
        let n = 2 + rng.usize_below(3);
        let ida: Vec<_> = (0..n).map(|_| a.register()).collect();
        let idb: Vec<_> = (0..n).map(|_| b.register()).collect();
        // slot numbering is identical on both sides by construction, so
        // one holds table mirrors both arenas
        let mut holds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut chains: Vec<Vec<u64>> = Vec::new();
        let mut next_hash: u64 = 1;
        for _ in 0..120 {
            let t = rng.usize_below(n);
            match rng.below(3) {
                // batch alloc vs k sequential allocs, sometimes published
                0 => {
                    let k = 1 + rng.usize_below(4);
                    match a.alloc_many(ida[t], k) {
                        Some(va) => {
                            let vb: Vec<usize> = (0..k)
                                .map(|_| b.alloc(idb[t]).expect("mirror: batch side succeeded"))
                                .collect();
                            if va != vb {
                                return Err(format!("alloc order diverged: {va:?} vs {vb:?}"));
                            }
                            if rng.below(2) == 0 {
                                let hashes: Vec<u64> =
                                    (0..k as u64).map(|i| next_hash + i).collect();
                                next_hash += k as u64;
                                let entries: Vec<(usize, u64)> =
                                    va.iter().copied().zip(hashes.iter().copied()).collect();
                                let ra = a.publish_many(ida[t], &entries);
                                let rb: Vec<bool> = entries
                                    .iter()
                                    .map(|&(p, h)| b.publish(idb[t], p, h))
                                    .collect();
                                if ra != rb {
                                    return Err(format!("publish diverged: {ra:?} vs {rb:?}"));
                                }
                                if ra.iter().all(|&ok| ok) {
                                    chains.push(hashes);
                                }
                            }
                            holds[t].extend(va);
                        }
                        None => {
                            if a.free_count() >= k {
                                return Err(format!(
                                    "alloc_many({k}) failed with {} free",
                                    a.free_count()
                                ));
                            }
                            if b.used() != a.used() {
                                return Err("failed batch alloc mutated state".into());
                            }
                        }
                    }
                }
                // batch release vs per-slot releases, same order
                1 => {
                    if holds[t].is_empty() {
                        continue;
                    }
                    let keep = rng.usize_below(holds[t].len());
                    let gone: Vec<usize> = holds[t].split_off(keep);
                    a.release_many(ida[t], &gone);
                    for &p in &gone {
                        b.release(idb[t], p);
                    }
                }
                // chain walk vs per-hash acquire loop (stale chains —
                // slots since freed and recycled — must miss identically)
                _ => {
                    if chains.is_empty() {
                        continue;
                    }
                    let hashes = chains[rng.usize_below(chains.len())].clone();
                    let ra = a.acquire_shared_run(ida[t], &hashes);
                    let mut rb = Vec::new();
                    for &h in &hashes {
                        match b.acquire_shared(idb[t], h) {
                            Some(p) => rb.push(p),
                            None => break,
                        }
                    }
                    if ra != rb {
                        return Err(format!("shared-run walk diverged: {ra:?} vs {rb:?}"));
                    }
                    holds[t].extend(ra);
                }
            }
            if a.used() != b.used() || a.free_count() != b.free_count() {
                return Err(format!(
                    "accounting diverged: used {}/{}, free {}/{}",
                    a.used(),
                    b.used(),
                    a.free_count(),
                    b.free_count()
                ));
            }
            if a.below_low_watermark(1) != b.below_low_watermark(1)
                || a.above_high_watermark() != b.above_high_watermark()
            {
                return Err("watermark verdicts diverged".into());
            }
            for p in 0..capacity {
                if a.refcount(p) != b.refcount(p) {
                    return Err(format!(
                        "refcount({p}) diverged: {} vs {}",
                        a.refcount(p),
                        b.refcount(p)
                    ));
                }
            }
            for (t2, hs) in holds.iter().enumerate() {
                if a.owned_by(ida[t2]) != hs.len() || b.owned_by(idb[t2]) != hs.len() {
                    return Err("per-tenant claims diverged from the mirror".into());
                }
            }
        }
        Ok(())
    });
}

/// Tentpole pin (PR 9): one SEQUENCE operation costs O(1) global lock
/// acquisitions, not O(blocks). A K-block prompt prefill and the drop of
/// a K-block sequence must each take <= 2 acquisitions — measured via
/// `stats()`, which is pure atomics and cannot perturb the count it
/// reads. This is the test that fails if anyone reintroduces a
/// lock-per-block loop in the seq_cache hot paths.
#[test]
fn seq_ops_take_constant_lock_acquisitions() {
    let arena = BlockManager::new(64);
    // 32 tokens at bs=4 -> 8 blocks: enough that an O(K) regression is
    // unambiguous against the <= 2 bound
    let tokens: Vec<(u32, [f32; 3])> = (0..32u32).map(|i| (i, [0.5; 3])).collect();
    let mut c = SeqCache::new_shared(4, 16, &arena);
    let before = arena.stats().lock_acquisitions;
    c.try_load_prefill(&tokens, 32).expect("64-block arena fits 8");
    let prefill_locks = arena.stats().lock_acquisitions - before;
    assert!(
        prefill_locks <= 2,
        "8-block prefill took {prefill_locks} global lock acquisitions (want <= 2)"
    );
    let before = arena.stats().lock_acquisitions;
    drop(c);
    let drop_locks = arena.stats().lock_acquisitions - before;
    assert!(
        drop_locks <= 2,
        "8-block drop took {drop_locks} global lock acquisitions (want <= 2)"
    );
    assert_eq!(arena.used(), 0, "drop returned every block");
}

/// Drain protocol end to end through `SeqCache`: when every free slot
/// sits leased in a peer worker's cache, a prefill must drain the peers
/// and succeed — NOT report a phantom ArenaDry — and leased slots must
/// read as free the whole time.
#[test]
fn prefill_drains_peer_slot_caches_instead_of_phantom_oom() {
    let arena = BlockManager::new(8);
    // the peer's first alloc leases the entire 8-slot arena into its
    // private stock (SLOT_CACHE_CAP = 8)
    let worker = arena.with_worker_cache();
    let wseq = worker.register();
    let held = worker.alloc(wseq).expect("first alloc leases the cache");
    assert_eq!(arena.used(), 1);
    assert_eq!(arena.free_count(), 7, "leased slots still count as free");
    assert_eq!(arena.stats().leased, 7);

    // 8 tokens at bs=2 -> 4 blocks, all only reachable via the drain
    let toks: Vec<(u32, [f32; 3])> = (0..8u32).map(|i| (i, [0.5; 3])).collect();
    let mut c = SeqCache::new_shared(2, 16, &arena);
    c.try_load_prefill(&toks, 8).expect("drain must satisfy the prefill");
    assert_eq!(c.n_blocks(), 4);
    assert_eq!(arena.stats().cache_drains, 1, "exactly one peer-cache drain");
    assert_eq!(arena.used(), 5);

    drop(c);
    worker.release(wseq, held);
    worker.unregister(wseq);
    drop(worker);
    assert_eq!(arena.used(), 0);
    assert_eq!(arena.free_count(), arena.capacity(), "nothing leaked through the drain");
}

#[test]
fn arena_capacity_is_a_hard_bound() {
    let arena = BlockManager::new(5);
    let mut a = SeqCache::new_shared(2, 16, &arena);
    let mut b = SeqCache::new_shared(2, 16, &arena);
    let mut allocated = 0;
    loop {
        let c = if allocated % 2 == 0 { &mut a } else { &mut b };
        match c.try_ensure_block() {
            BlockAlloc::Ready => {
                c.append([0.5; 3]);
                c.append([0.5; 3]); // fill the page (bs = 2)
                allocated += 1;
            }
            BlockAlloc::ArenaDry => break,
            BlockAlloc::BucketFull => unreachable!("bucket 16 > capacity 5"),
        }
    }
    assert_eq!(allocated, 5, "exactly capacity blocks were ever handed out");
    assert_eq!(arena.used(), 5);
    assert_eq!(arena.stats().peak_used, 5);
}
