//! Serving-stack integration: scheduler (continuous batching), engine loop
//! thread, and the TCP JSON-lines frontend.
//!
//! Requires the `xla` feature (real PJRT bindings) and `make artifacts`.
#![cfg(feature = "xla")]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use paged_eviction::runtime::Engine;
use paged_eviction::scheduler::{Request, SchedConfig, Scheduler};
use paged_eviction::server::serve::{serve_forever, spawn_engine, ServeOpts};
use paged_eviction::util::json::Json;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::workload::recall;

fn artifacts() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg() -> SchedConfig {
    SchedConfig {
        model: "sim-1b".into(),
        page_size: 16,
        max_concurrency: 4,
        max_live_blocks: 512,
        ..SchedConfig::default()
    }
}

#[test]
fn scheduler_completes_mixed_batch() {
    let engine = Engine::new(artifacts()).unwrap();
    let mut sched = Scheduler::new(&engine, cfg()).unwrap();
    let mut rng = Pcg32::new(11);
    // mixed policies + budgets in one batch
    for (i, policy) in ["paged", "streaming", "full", "inverse_key_norm", "keydiff", "paged"]
        .iter()
        .enumerate()
    {
        let p = recall::make_prompt(&mut rng, 96, 0.3);
        let mut req = Request::new(i as u64 + 1, p.tokens, 12);
        req.budget = 64;
        req.policy = policy.to_string();
        sched.submit(req);
    }
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 6);
    for o in &outs {
        assert_eq!(o.tokens.len(), 12, "req {}", o.id);
        assert!(o.ttft_s >= 0.0 && o.tpot_s > 0.0);
    }
    assert!(sched.is_idle());
    assert_eq!(sched.total_generated, 6 * 12);
    assert!(sched.throughput_tok_s() > 0.0);
    assert!(sched.tpot.len() == 6);
}

#[test]
fn scheduler_interleaves_continuous_batching() {
    // With max_concurrency 2 and 4 requests, the scheduler must admit new
    // work as old sequences retire (continuous batching), never exceeding
    // the concurrency cap.
    let engine = Engine::new(artifacts()).unwrap();
    let mut sched =
        Scheduler::new(&engine, SchedConfig { max_concurrency: 2, ..cfg() }).unwrap();
    let mut rng = Pcg32::new(12);
    for i in 0..4 {
        let p = recall::make_prompt(&mut rng, 64, 0.5);
        let mut req = Request::new(i + 1, p.tokens, 6);
        req.budget = 64;
        sched.submit(req);
    }
    let mut max_running = 0;
    while !sched.is_idle() {
        sched.step().unwrap();
        max_running = max_running.max(sched.running());
    }
    assert_eq!(sched.take_finished().len(), 4);
    assert!(max_running <= 2, "concurrency cap violated: {max_running}");
}

#[test]
fn admission_respects_block_capacity() {
    // Tiny global pool: second request must wait until the first finishes.
    let engine = Engine::new(artifacts()).unwrap();
    let mut sched = Scheduler::new(
        &engine,
        SchedConfig { max_concurrency: 4, max_live_blocks: 8, ..cfg() },
    )
    .unwrap();
    let mut rng = Pcg32::new(13);
    for i in 0..2 {
        let p = recall::make_prompt(&mut rng, 64, 0.5);
        let mut req = Request::new(i + 1, p.tokens, 4);
        req.budget = 64; // prefill claims 4 blocks per request
        sched.submit(req);
    }
    // low watermark = floor(0.85 * 8) = 6 blocks: the first admission
    // (4 blocks) fits, the second (4 + 4 > 6) stays queued
    sched.step().unwrap();
    assert_eq!(sched.running(), 1);
    assert_eq!(sched.pending(), 1);
    let outs = sched.run_to_completion().unwrap();
    assert_eq!(outs.len(), 2, "queued request must eventually be served");
}

#[test]
fn eos_token_stops_generation() {
    let engine = Engine::new(artifacts()).unwrap();
    let mut sched = Scheduler::new(&engine, cfg()).unwrap();
    let mut rng = Pcg32::new(14);
    let p = recall::make_prompt(&mut rng, 64, 0.5);
    let mut req = Request::new(1, p.tokens.clone(), 64);
    req.budget = 128;
    // Greedy decoding of this prompt produces some token; find it first.
    sched.submit(req.clone());
    let out = sched.run_to_completion().unwrap().pop().unwrap();
    let first = out.tokens[0];
    // Now resubmit with that token as EOS: generation must stop at 1 token.
    let mut req2 = Request::new(2, p.tokens, 64);
    req2.budget = 128;
    req2.eos_token = Some(first);
    sched.submit(req2);
    let out2 = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(out2.tokens.len(), 1);
    assert_eq!(out2.finish, paged_eviction::scheduler::FinishReason::Eos);
}

#[test]
fn tcp_roundtrip_text_and_ids() {
    let (handle, _join) = spawn_engine(artifacts(), cfg()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = serve_forever(listener, handle, ServeOpts::default());
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // ids request
    writeln!(
        w,
        r#"{{"id": 5, "prompt": [1,33,2,34,1,33], "max_new_tokens": 3, "budget": 64}}"#
    )
    .unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("id").unwrap().as_usize(), Some(5));
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));

    // text request (auto id)
    writeln!(w, r#"{{"text": "hello world", "max_new_tokens": 2}}"#).unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    assert!(j.get("tpot_ms").unwrap().as_f64().unwrap() >= 0.0);

    // malformed request gets an error object, connection stays usable
    writeln!(w, "not json").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("error"));
}

#[test]
fn concurrent_tcp_clients() {
    let (handle, _join) = spawn_engine(artifacts(), cfg()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = serve_forever(listener, handle, ServeOpts::default());
    });
    let mut joins = Vec::new();
    for c in 0..3u64 {
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg32::with_stream(20, c);
            let stream = TcpStream::connect(addr).unwrap();
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream);
            for i in 0..2 {
                let p = recall::make_prompt(&mut rng, 64, 0.4);
                let ids: Vec<String> = p.tokens.iter().map(|t| t.to_string()).collect();
                writeln!(
                    w,
                    r#"{{"id": {}, "prompt": [{}], "max_new_tokens": 4, "budget": 64}}"#,
                    c * 10 + i + 1,
                    ids.join(",")
                )
                .unwrap();
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let j = Json::parse(line.trim()).unwrap();
                assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
