//! Swap-to-host preemption + watermark admission on the deterministic sim
//! backend (no PJRT).
//!
//! The sim backend's logits are a pure function of token history, so
//! greedy outputs are bit-deterministic and independent of physical block
//! layout. That lets these tests pin the strongest property the swap path
//! must have: a sequence that is preempted, parked in the host swap pool
//! and later RESTORED produces bit-identical output to (a) the same
//! contended run readmitted through the recompute-and-replay path and
//! (b) an uncontended run that was never preempted at all — while
//! `CacheStats`/`RequestOutput` distinguish `swaps` (restored) from
//! `preemptions` (total evictions).

use paged_eviction::eviction::{make_policy, REGISTRY};
use paged_eviction::kvcache::{BlockManager, SeqCache};
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::SimBackend;
use paged_eviction::scheduler::backend::{DecodeBackend, HostSnapshot, Prefilled, Restored};
use paged_eviction::scheduler::{FinishReason, Request, RequestOutput, SchedConfig, Scheduler};
use paged_eviction::util::propcheck;
use paged_eviction::util::rng::Pcg32;

fn cfg(page: usize, conc: usize, arena_blocks: usize) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        // hard-capacity band by default; individual tests open it up
        watermark_low: 1.0,
        watermark_high: 1.0,
        swap_bytes: 0,
        // prefix caching stays ON here: every prompt is distinct random,
        // so it must be a no-op — which these exact-accounting tests
        // silently verify on top of their swap assertions
        prefix_cache: true,
        ..SchedConfig::default()
    }
}

fn mk_req(id: u64, prompt: Vec<u32>, gen: usize, budget: usize, policy: &str) -> Request {
    let mut r = Request::new(id, prompt, gen);
    r.budget = budget;
    r.policy = policy.to_string();
    r
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

/// Run a request set to completion and return outputs sorted by id.
fn run(cfg: SchedConfig, reqs: &[Request]) -> (Vec<RequestOutput>, Scheduler<SimBackend>) {
    let mut sched = Scheduler::new_sim(cfg);
    for r in reqs {
        sched.submit(r.clone());
    }
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (outs, sched)
}

/// Two "full"-policy sequences in an arena that cannot absorb both of
/// their growth ladders: the youngest gets preempted mid-decode.
fn contended_pair() -> Vec<Request> {
    let mut rng = Pcg32::new(7);
    let pa = rand_prompt(&mut rng, 64);
    let pb = rand_prompt(&mut rng, 64);
    vec![
        mk_req(1, pa, 24, 1024, "full"),
        mk_req(2, pb, 24, 1024, "full"),
    ]
}

/// The acceptance property: swap-restored output == recompute output ==
/// uncontended output, bit for bit, with swaps/preemptions distinguished.
#[test]
fn swap_restore_matches_recompute_and_uncontended_bit_for_bit() {
    let reqs = contended_pair();

    let (uncontended, s0) = run(cfg(4, 2, 10_000), &reqs);
    assert_eq!(s0.preemptions, 0, "ample arena must not preempt");

    // recompute leg: swap disabled, 36-block arena forces a preemption
    let (recompute, s1) = run(cfg(4, 2, 36), &reqs);
    assert!(s1.preemptions >= 1, "36 blocks cannot hold both ladders");
    assert_eq!(s1.swap_outs, 0, "swap disabled parks nothing");
    assert_eq!(s1.swap_restores, 0);

    // swap leg: same pressure, victims park in a roomy host pool
    let (swapped, s2) = run(
        SchedConfig { swap_bytes: 16 << 20, ..cfg(4, 2, 36) },
        &reqs,
    );
    assert!(s2.preemptions >= 1);
    assert!(s2.swap_outs >= 1, "the victim must be parked in the pool");
    assert!(s2.swap_restores >= 1, "and readmitted by restore");
    assert_eq!(s2.swap_pool().len(), 0, "restore drains the pool");

    for ((u, r), s) in uncontended.iter().zip(&recompute).zip(&swapped) {
        assert_eq!(u.id, r.id);
        assert_eq!(u.id, s.id);
        assert_eq!(u.finish, FinishReason::MaxTokens);
        assert_eq!(
            r.tokens, u.tokens,
            "req {}: recompute readmission drifted from the uncontended run",
            u.id
        );
        assert_eq!(
            s.tokens, u.tokens,
            "req {}: swap-restored readmission drifted from the uncontended run",
            u.id
        );
    }

    // stats distinguish the paths: the recompute victim has preemptions
    // but no swaps; the swap victim has both, and CacheStats agrees.
    let rv = &recompute[1];
    assert!(rv.preemptions >= 1, "youngest sequence was the victim");
    assert_eq!(rv.swaps, 0, "recompute leg restored nothing");
    let sv = &swapped[1];
    assert!(sv.preemptions >= 1);
    assert!(sv.swaps >= 1, "swap leg restored the victim");
    assert_eq!(sv.cache_stats.preemptions, sv.preemptions as u64);
    assert_eq!(sv.cache_stats.swaps, sv.swaps as u64);
    assert!(sv.swaps <= sv.preemptions, "swaps is a subset of preemptions");
    // the elder sequence ran through untouched in both legs
    assert_eq!(recompute[0].preemptions, 0);
    assert_eq!(swapped[0].preemptions, 0);
    assert_eq!(swapped[0].swaps, 0);
}

/// A pool too small for even one snapshot parks nothing: every victim
/// falls back to recompute, and outputs are still bit-identical.
#[test]
fn undersized_swap_pool_falls_back_to_recompute() {
    let reqs = contended_pair();
    let (uncontended, _) = run(cfg(4, 2, 10_000), &reqs);
    let (outs, sched) = run(
        SchedConfig { swap_bytes: 64, ..cfg(4, 2, 36) }, // 64 BYTES
        &reqs,
    );
    assert!(sched.preemptions >= 1);
    assert_eq!(sched.swap_outs, 0, "nothing fits a 64-byte pool");
    assert_eq!(sched.swap_restores, 0);
    for (o, u) in outs.iter().zip(&uncontended) {
        assert_eq!(o.tokens, u.tokens, "req {}: fallback lost work", o.id);
    }
    assert_eq!(outs[1].swaps, 0);
    assert!(outs[1].preemptions >= 1);
}

/// Measure the host bytes of a full-policy sim sequence snapshotted at
/// `blocks` blocks, by driving the identical prefill/decode/grow path the
/// scheduler drives.
fn snapshot_bytes_at_blocks(prompt: &[u32], blocks: usize) -> usize {
    let arena = BlockManager::new(10_000);
    let mut be = SimBackend::new(4);
    let Prefilled::Ready { mut seq, logits } = be
        .prefill(&arena, prompt, 1024, make_policy("full").unwrap())
        .unwrap()
    else {
        panic!("prefill OOM on a 10k arena")
    };
    let mut tok = argmax(&logits);
    while seq.cache.n_blocks() < blocks {
        while !seq.cache.ensure_block() {
            be.grow_bucket(&mut seq).unwrap();
        }
        let mut b = [(&mut seq, tok)];
        tok = argmax(&be.decode_batch(&mut b).pop().unwrap().unwrap());
    }
    be.snapshot(&seq).expect("sim backend always snapshots").host_bytes()
}

/// SwapPool byte-cap eviction end to end: two victims contend for a pool
/// sized to hold only one snapshot. The OLDEST parked snapshot is
/// LRU-dropped, its victim transparently falls back to recompute, and
/// every output is still bit-identical to the uncontended run.
#[test]
fn lru_dropped_snapshot_falls_back_to_recompute_with_identical_output() {
    let mut rng = Pcg32::new(21);
    let reqs = vec![
        mk_req(1, rand_prompt(&mut rng, 64), 40, 1024, "full"),
        mk_req(2, rand_prompt(&mut rng, 64), 40, 1024, "full"),
        mk_req(3, rand_prompt(&mut rng, 64), 8, 1024, "full"),
    ];
    let (uncontended, s0) = run(cfg(4, 3, 10_000), &reqs);
    assert_eq!(s0.preemptions, 0);

    // Pool sized for ~1.25x the bigger victim's snapshot (#2 is preempted
    // at ~24 blocks): it holds one snapshot, never two.
    let cap = snapshot_bytes_at_blocks(&reqs[1].prompt, 24) * 5 / 4;
    // 48 blocks: all three 16-block prefills fit exactly; round 1 already
    // preempts #3 (reservation finds the arena dry), and the ladders of
    // #1/#2 (26 blocks each) force a second preemption later.
    let (outs, sched) = run(SchedConfig { swap_bytes: cap, ..cfg(4, 3, 48) }, &reqs);

    assert!(sched.preemptions >= 2, "two victims under this pressure");
    assert!(sched.swap_outs >= 2, "both victims were parked");
    assert!(
        sched.swap_pool().dropped() >= 1,
        "the byte cap must LRU-drop the older snapshot"
    );
    assert!(sched.swap_restores >= 1, "the surviving snapshot restores");
    assert!(
        sched.preemptions > sched.swap_restores,
        "the dropped victim's readmission went the recompute path"
    );
    for (o, u) in outs.iter().zip(&uncontended) {
        assert_eq!(o.id, u.id);
        assert_eq!(o.finish, FinishReason::MaxTokens);
        assert_eq!(
            o.tokens, u.tokens,
            "req {}: a dropped snapshot must degrade to recompute, not lose work",
            o.id
        );
    }
}

/// Watermark admission (the paper's Limitation-1 fix): a request whose
/// WORST-CASE estimate exceeds free memory is admitted anyway, because
/// the gate charges only the blocks prefill claims now and usage sits
/// below the low watermark. Bounded policies then never grow into the
/// band, so the optimism is free.
#[test]
fn watermark_admission_admits_what_worst_case_estimates_reject() {
    let page = 4;
    let mut rng = Pcg32::new(8);
    let reqs = vec![
        mk_req(1, rand_prompt(&mut rng, 32), 60, 16, "paged"),
        mk_req(2, rand_prompt(&mut rng, 32), 60, 16, "paged"),
    ];
    // Worst case per request: ceil((16 + 60) / 4) = 19 blocks. After the
    // first admission (4 blocks) only 16 are free, so a worst-case gate
    // serializes the pair; the watermark gate sees 4 + 4 <= low mark
    // floor(0.85 * 20) = 17 and admits both at once.
    let mut sched = Scheduler::new_sim(SchedConfig {
        watermark_low: 0.85,
        watermark_high: 0.95,
        ..cfg(page, 2, 20)
    });
    for r in &reqs {
        sched.submit(r.clone());
    }
    let rep = sched.step().unwrap();
    assert_eq!(rep.prefilled, 2, "both admitted below the low watermark");
    let mut outs = sched.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::MaxTokens, "req {}", o.id);
        assert_eq!(o.tokens.len(), 60);
        assert_eq!(o.preemptions, 0, "bounded budgets never cross the band");
    }
    assert_eq!(sched.preemptions, 0);
}

/// Above the high watermark the scheduler preempts PROACTIVELY: pressure
/// is relieved before the arena ever runs hard dry, and the victim's
/// restored output is still bit-identical.
#[test]
fn high_watermark_preempts_before_exhaustion() {
    let mut rng = Pcg32::new(9);
    let reqs = vec![
        mk_req(1, rand_prompt(&mut rng, 32), 24, 1024, "full"),
        mk_req(2, rand_prompt(&mut rng, 32), 24, 1024, "full"),
    ];
    let (uncontended, _) = run(cfg(4, 2, 10_000), &reqs);
    // low = 16 blocks, high = 24 blocks, capacity 32. Both 8-block
    // prefills are admitted at the low mark; joint growth (14 blocks
    // each) crosses the high mark long before raw capacity.
    let (outs, sched) = run(
        SchedConfig {
            watermark_low: 0.5,
            watermark_high: 0.75,
            swap_bytes: 16 << 20,
            ..cfg(4, 2, 32)
        },
        &reqs,
    );
    assert!(sched.preemptions >= 1, "the high watermark must trip");
    assert!(sched.swap_restores >= 1, "victim comes back via restore");
    let peak = sched.arena().stats().peak_used;
    assert!(
        peak < 32,
        "proactive preemption must fire before exhaustion (peak {peak})"
    );
    for (o, u) in outs.iter().zip(&uncontended) {
        assert_eq!(o.tokens, u.tokens, "req {}: watermark path lost work", o.id);
    }
}

/// Snapshot/restore round-trips for EVERY eviction policy: a sequence
/// suspended mid-decode and restored into a fresh arena continues with
/// bit-identical logits, cache serialization and policy decisions.
#[test]
fn property_snapshot_restore_roundtrip_every_policy() {
    propcheck::quick("swap-roundtrip", |rng: &mut Pcg32| {
        let page = *rng.choose(&[2usize, 4, 8]);
        let plen = page * (2 + rng.usize_below(8)) + rng.usize_below(page);
        let budget = page * (2 + rng.usize_below(6));
        let warm = rng.usize_below(3 * page);
        let tail = 1 + rng.usize_below(2 * page);
        let prompt: Vec<u32> = (0..plen).map(|_| rng.below(200)).collect();
        // every registry entry, feedback-consuming policies included —
        // new policies are swap-roundtrip-tested the day they register
        for info in REGISTRY {
            let policy = info.name;
            let arena = BlockManager::new(10_000);
            let mut be = SimBackend::new(page);
            let Prefilled::Ready { mut seq, logits } = be
                .prefill(&arena, &prompt, budget, make_policy(policy).unwrap())
                .map_err(|e| format!("{policy}: prefill failed: {e:#}"))?
            else {
                return Err(format!("{policy}: unexpected prefill OOM"));
            };
            let mut tok = argmax(&logits);
            for _ in 0..warm {
                while !seq.cache.ensure_block() {
                    be.grow_bucket(&mut seq).unwrap();
                }
                let mut b = [(&mut seq, tok)];
                tok = argmax(&be.decode_batch(&mut b).pop().unwrap().unwrap());
            }

            // suspend into a DIFFERENT arena, as a real swap would
            let snap = be.snapshot(&seq).expect("sim backend always snapshots");
            if snap.arena_blocks() != seq.cache.n_blocks() {
                return Err(format!("{policy}: snapshot block count drifted"));
            }
            let arena2 = BlockManager::new(10_000);
            let Restored::Ready(mut twin) = be
                .restore(&arena2, &snap)
                .map_err(|e| format!("{policy}: restore failed: {e:#}"))?
            else {
                return Err(format!("{policy}: unexpected restore OOM"));
            };
            twin.cache
                .check_invariants()
                .map_err(|e| format!("{policy}: restored invariants: {e}"))?;
            assert_same_cache(policy, &seq.cache, &twin.cache)?;

            // both must continue bit-identically
            let mut tok2 = tok;
            for step in 0..tail {
                for (s, t) in [(&mut seq, &mut tok), (&mut twin, &mut tok2)] {
                    while !s.cache.ensure_block() {
                        be.grow_bucket(s).unwrap();
                    }
                    let mut b = [(&mut *s, *t)];
                    *t = argmax(&be.decode_batch(&mut b).pop().unwrap().unwrap());
                }
                if tok != tok2 {
                    return Err(format!("{policy}: tokens diverged at step {step}"));
                }
                assert_same_cache(policy, &seq.cache, &twin.cache)?;
            }
        }
        Ok(())
    });
}

/// Serialization-relevant equality between two caches (what the decode
/// graph and the policies can observe).
fn assert_same_cache(policy: &str, a: &SeqCache, b: &SeqCache) -> Result<(), String> {
    if a.capacity_blocks() != b.capacity_blocks() {
        return Err(format!("{policy}: bucket drifted"));
    }
    let nb = a.capacity_blocks();
    if a.block_table(nb) != b.block_table(nb) {
        return Err(format!("{policy}: block table drifted"));
    }
    if a.valid_mask(nb) != b.valid_mask(nb) {
        return Err(format!("{policy}: validity mask drifted"));
    }
    if a.live_token_list() != b.live_token_list() {
        return Err(format!("{policy}: live token view drifted"));
    }
    if a.next_position() != b.next_position() {
        return Err(format!("{policy}: next_position drifted"));
    }
    if a.stats != b.stats {
        return Err(format!("{policy}: cache stats drifted"));
    }
    Ok(())
}
