//! Proof that the steady-state decode metadata path performs **zero heap
//! allocations**: a counting global allocator wraps the system allocator
//! (this test binary only), and the block-table / validity-mask accessors
//! plus the structured `post_append` scan are asserted to allocate nothing
//! per decode step. The unstructured scan is now also strictly
//! allocation-free: the kill list rides inline in the returned `Decision`
//! (`KillList` small-vec) instead of a per-step `Vec`.
//!
//! Kept in its own integration-test binary so the global allocator and the
//! single-threaded measurement cannot interfere with other tests.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use paged_eviction::eviction::{make_policy, Decision};
use paged_eviction::kvcache::SeqCache;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_decode_metadata_path_is_allocation_free() {
    let bs = 16usize;
    let cap = 64usize;
    let budget = 256usize;
    let mut cache = SeqCache::new(bs, cap);
    let pre: Vec<(u32, [f32; 3])> =
        (0..budget as u32).map(|i| (i, [0.5 + (i % 7) as f32 * 0.01; 3])).collect();
    cache.load_prefill(&pre, budget as u32);

    // --- structured (paged) path: strictly zero allocations ---
    let paged = make_policy("paged").unwrap();
    // warm up one full block cycle so every buffer reaches steady state
    for _ in 0..(2 * bs) {
        assert!(cache.ensure_block());
        cache.append([0.4; 3]);
        if let Decision::EvictBlock(i) = paged.post_append(&cache, budget) {
            cache.evict_block(i);
        }
    }
    let mut total_serialize = 0u64;
    let mut total_scan = 0u64;
    for step in 0..(4 * bs) {
        assert!(cache.ensure_block(), "step {step}: pool exhausted");
        cache.append([0.4 + (step % 5) as f32 * 0.01; 3]);

        let nb = cache.capacity_blocks();
        let before = allocs();
        let table = cache.block_table(nb);
        let mask = cache.valid_mask(nb);
        let sum = table.iter().map(|&x| x as i64).sum::<i64>()
            + mask.iter().map(|&x| x as i64).sum::<i64>();
        let after_serialize = allocs();
        let decision = paged.post_append(&cache, budget);
        let after_scan = allocs();
        std::hint::black_box(sum);

        total_serialize += after_serialize - before;
        total_scan += after_scan - after_serialize;
        if let Decision::EvictBlock(i) = decision {
            cache.evict_block(i);
        }
    }
    assert_eq!(total_serialize, 0, "block_table/valid_mask must not allocate");
    assert_eq!(total_scan, 0, "paged post_append scan must not allocate");

    // --- unstructured (inverse_key_norm) path: the reusable scratch keeps
    // the global scan allocation-free, and the kill list is an inline
    // small-vec — zero allocations per step, end to end ---
    let ikn = make_policy("inverse_key_norm").unwrap();
    let mut cache = SeqCache::new(bs, cap);
    let pre: Vec<(u32, [f32; 3])> =
        (0..budget as u32).map(|i| (i, [0.0, ((i * 7919) % 97) as f32, 0.0])).collect();
    cache.load_prefill(&pre, budget as u32);
    for step in 0..8 {
        // warm-up: grows the scratch buffer to its steady-state capacity
        assert!(cache.ensure_block(), "warmup {step}");
        cache.append([0.0, ((step * 31) % 13) as f32, 0.0]);
        if let Decision::KillTokens(ts) = ikn.post_append(&cache, budget) {
            for (bi, off) in ts {
                cache.kill_token(bi, off);
            }
        }
    }
    let mut worst_step = 0u64;
    for step in 0..(2 * bs) {
        assert!(cache.ensure_block(), "step {step}");
        cache.append([0.0, ((step * 31) % 13) as f32, 0.0]);
        let before = allocs();
        let decision = ikn.post_append(&cache, budget);
        let spent = allocs() - before;
        worst_step = worst_step.max(spent);
        if let Decision::KillTokens(ts) = decision {
            for (bi, off) in ts {
                cache.kill_token(bi, off);
            }
        }
    }
    assert_eq!(
        worst_step, 0,
        "unstructured post_append must be allocation-free end to end \
         (inline KillList), saw {worst_step} allocations in one step"
    );
}
