//! Hot-path regression suite for the zero-allocation decode path:
//!
//!  * the incrementally maintained block-table / validity-mask buffers must
//!    stay **bit-identical** to a from-scratch rebuild across arbitrary
//!    append / evict_block / kill_token / grow sequences — both random op
//!    soup and real policy-driven decode loops;
//!  * the dirty-region tracking must cover every write (patching a stale
//!    copy through the reported ranges reproduces the live buffers);
//!  * the parallel episode simulator must be bit-identical to the serial
//!    path (episodes are seed-deterministic and order-accumulated).

use paged_eviction::eviction::{make_policy, Decision, REGISTRY};
use paged_eviction::kvcache::SeqCache;
use paged_eviction::sim::attention_sim::{simulate_mean, simulate_mean_serial, SimConfig};
use paged_eviction::sim::datasets::dataset;
use paged_eviction::util::propcheck;
use paged_eviction::util::rng::Pcg32;

fn rsc(rng: &mut Pcg32) -> [f32; 3] {
    [rng.f32(), rng.f32(), rng.f32()]
}

/// One random cache mutation, shared by the properties below.
fn random_op(c: &mut SeqCache, rng: &mut Pcg32) {
    match rng.below(10) {
        0..=5 => {
            if c.ensure_block() {
                let sc = rsc(rng);
                c.append(sc);
            } else if c.capacity_blocks() < 64 {
                c.grow(c.capacity_blocks() + 2);
            }
        }
        6..=7 => {
            if c.n_blocks() > 1 {
                let idx = rng.usize_below(c.n_blocks() - 1);
                c.evict_block(idx);
            }
        }
        _ => {
            let live = c.live_token_list();
            if live.len() > 1 {
                let (bi, off, _, _) = live[rng.usize_below(live.len())];
                c.kill_token(bi, off);
            }
        }
    }
}

#[test]
fn incremental_buffers_match_rebuild_under_random_ops() {
    propcheck::quick("incremental-vs-rebuild", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[2usize, 4, 8, 16]);
        let cap = 4 + rng.usize_below(12);
        let mut c = SeqCache::new(bs, cap);
        let pre = rng.usize_below(cap * bs / 2) + 1;
        let toks: Vec<(u32, [f32; 3])> =
            (0..pre as u32).map(|i| (i, [0.1, 0.2, 0.3])).collect();
        c.load_prefill(&toks, pre as u32);
        for step in 0..200 {
            random_op(&mut c, rng);
            let nb = c.capacity_blocks();
            if c.block_table(nb) != c.rebuild_block_table(nb).as_slice() {
                return Err(format!("step {step}: block table drifted from rebuild"));
            }
            if c.valid_mask(nb) != c.rebuild_valid_mask(nb).as_slice() {
                return Err(format!("step {step}: valid mask drifted from rebuild"));
            }
        }
        Ok(())
    });
}

#[test]
fn incremental_buffers_survive_every_policy_decode_loop() {
    propcheck::quick("policy-decode-incremental", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[4usize, 8, 16]);
        let budget_blocks = 2 + rng.usize_below(4);
        let budget = budget_blocks * bs;
        // every registry entry, so new policies (feedback-consuming ones
        // included, on their proxy path here) join the property at birth
        for info in REGISTRY {
            let name = info.name;
            if name == "full" {
                continue; // unbounded; covered by the random-op property
            }
            let p = make_policy(name).unwrap();
            let cap = budget_blocks + 3;
            let mut c = SeqCache::new(bs, cap);
            let pre: Vec<(u32, [f32; 3])> =
                (0..budget as u32).map(|i| (i, rsc(rng))).collect();
            c.load_prefill(&pre, budget as u32);
            for step in 0..(3 * bs) {
                if !c.ensure_block() {
                    // unstructured fragmentation can exceed the nominal
                    // block budget (paper Limitation 1) — grow the bucket
                    c.grow(c.capacity_blocks() + 2);
                    assert!(c.ensure_block());
                }
                let sc = rsc(rng);
                c.append(sc);
                match p.post_append(&c, budget) {
                    Decision::Keep => {}
                    Decision::EvictBlock(i) => c.evict_block(i),
                    Decision::KillTokens(ts) => {
                        for (bi, off) in ts {
                            c.kill_token(bi, off);
                        }
                    }
                }
                let nb = c.capacity_blocks();
                if c.block_table(nb) != c.rebuild_block_table(nb).as_slice() {
                    return Err(format!("{name} step {step}: table drift"));
                }
                if c.valid_mask(nb) != c.rebuild_valid_mask(nb).as_slice() {
                    return Err(format!("{name} step {step}: mask drift"));
                }
                c.check_invariants().map_err(|e| format!("{name} step {step}: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn dirty_regions_patch_a_stale_copy_exactly() {
    propcheck::quick("dirty-region-patch", |rng: &mut Pcg32| {
        let bs = *rng.choose(&[2usize, 4, 8]);
        let cap = 4 + rng.usize_below(8);
        let mut c = SeqCache::new(bs, cap);
        let pre = rng.usize_below(cap * bs / 2) + 1;
        let toks: Vec<(u32, [f32; 3])> =
            (0..pre as u32).map(|i| (i, [0.5, 0.5, 0.5])).collect();
        c.load_prefill(&toks, pre as u32);
        let mut nb = c.capacity_blocks();
        let mut shadow_t = c.block_table(nb).to_vec();
        let mut shadow_m = c.valid_mask(nb).to_vec();
        c.clear_dirty();
        for step in 0..120 {
            random_op(&mut c, rng);
            nb = c.capacity_blocks();
            // poison any grown region; the dirty range must cover it
            shadow_t.resize(nb, -1);
            shadow_m.resize(nb * bs, -1.0);
            if let Some(r) = c.table_dirty() {
                shadow_t[r.clone()].copy_from_slice(&c.block_table(nb)[r]);
            }
            if let Some(r) = c.mask_dirty() {
                shadow_m[r.clone()].copy_from_slice(&c.valid_mask(nb)[r]);
            }
            c.clear_dirty();
            if shadow_t != c.block_table(nb) {
                return Err(format!("step {step}: table dirty range missed a write"));
            }
            if shadow_m != c.valid_mask(nb) {
                return Err(format!("step {step}: mask dirty range missed a write"));
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_simulate_mean_is_bit_identical_to_serial() {
    for (ds, pol) in [
        ("govreport", "paged"),
        ("hotpotqa", "streaming"),
        ("qasper", "keydiff"),
        ("multifieldqa", "inverse_key_norm"),
        ("multinews", "self_attn"),
        ("govreport", "attention_gate"),
    ] {
        let d = dataset(ds).unwrap();
        let p = make_policy(pol).unwrap();
        let cfg = SimConfig { budget: 512, ..Default::default() };
        let serial = simulate_mean_serial(d, p.as_ref(), &cfg, 8);
        let parallel = simulate_mean(d, p.as_ref(), &cfg, 8);
        assert_eq!(
            serial.score.to_bits(),
            parallel.score.to_bits(),
            "{ds}/{pol}: parallel score differs from serial"
        );
        assert_eq!(serial.coverage.to_bits(), parallel.coverage.to_bits(), "{ds}/{pol}");
        assert_eq!(
            serial.needles_retained.to_bits(),
            parallel.needles_retained.to_bits(),
            "{ds}/{pol}"
        );
        assert_eq!(
            (serial.partial_blocks, serial.table_updates, serial.mask_updates),
            (parallel.partial_blocks, parallel.table_updates, parallel.mask_updates),
            "{ds}/{pol}"
        );
    }
}
