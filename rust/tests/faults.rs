//! Chaos suite: scheduler recovery under deterministic fault injection.
//!
//! Every test runs a faulted session against a fault-free twin (same
//! prompts, same config, empty [`FaultPlan`]) and asserts the recovery
//! invariants the scheduler claims: every submitted request terminates
//! with exactly ONE `Finished` event, transiently-faulted survivors are
//! bit-identical to the twin, terminally-failed requests keep their
//! partial tokens (a prefix of the twin's output) and release every
//! arena page and swap byte they held.

use paged_eviction::api::{RequestBuilder, RequestHandle, SeqEvent, Session};
use paged_eviction::runtime::{FaultPlan, FaultyBackend, SimBackend};
use paged_eviction::scheduler::{FinishReason, Request, RequestOutput, SchedConfig, Scheduler};
use paged_eviction::util::rng::Pcg32;

type FaultySession = Session<FaultyBackend<SimBackend>>;
type FaultyHandle = RequestHandle<FaultyBackend<SimBackend>>;

/// Hard-capacity watermarks, no swap, no prefix cache: the exact-
/// arithmetic baseline (individual tests open features up).
fn cfg(page: usize, conc: usize, arena_blocks: usize) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: page,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        watermark_low: 1.0,
        watermark_high: 1.0,
        swap_bytes: 0,
        prefix_cache: false,
        ..SchedConfig::default()
    }
}

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

fn finished_of(events: &[SeqEvent]) -> Option<RequestOutput> {
    events.iter().find_map(|e| match e {
        SeqEvent::Finished(o) => Some(o.clone()),
        _ => None,
    })
}

fn n_finished(events: &[SeqEvent]) -> usize {
    events
        .iter()
        .filter(|e| matches!(e, SeqEvent::Finished(_)))
        .count()
}

/// Drive a session to idle, draining every handle's events as they come.
fn run_session(session: &FaultySession, handles: &[FaultyHandle]) -> Vec<Vec<SeqEvent>> {
    let mut streams: Vec<Vec<SeqEvent>> = vec![Vec::new(); handles.len()];
    while !session.is_idle() {
        session.step().unwrap();
        for (h, s) in handles.iter().zip(streams.iter_mut()) {
            s.extend(h.drain());
        }
    }
    for (h, s) in handles.iter().zip(streams.iter_mut()) {
        s.extend(h.drain());
    }
    streams
}

/// One request spec: (prompt, max_new_tokens, budget, policy).
type Spec = (Vec<u32>, usize, usize, &'static str);

fn submit_all(session: &FaultySession, specs: &[Spec]) -> Vec<FaultyHandle> {
    specs
        .iter()
        .map(|(p, gen, budget, pol)| {
            session
                .submit(
                    RequestBuilder::new(p.clone())
                        .max_new_tokens(*gen)
                        .budget(*budget)
                        .policy(*pol),
                )
                .unwrap()
        })
        .collect()
}

/// The fault-matrix driver: run `specs` under `plan` and under an empty
/// plan (the twin), check the universal invariants, and hand back the
/// faulted session + streams + twin outputs for class-specific asserts.
fn run_twinned(
    cfg: &SchedConfig,
    plan: FaultPlan,
    specs: &[Spec],
) -> (FaultySession, Vec<Vec<SeqEvent>>, Vec<RequestOutput>) {
    let twin = Session::new_sim_faulty(cfg.clone(), FaultPlan::new());
    let twin_handles = submit_all(&twin, specs);
    let twin_streams = run_session(&twin, &twin_handles);
    let twin_outs: Vec<RequestOutput> = twin_streams
        .iter()
        .map(|s| finished_of(s).expect("twin stream must terminate in Finished"))
        .collect();
    assert_eq!(twin.with_scheduler(|s| s.arena().used()), 0, "twin leaks");

    let run = Session::new_sim_faulty(cfg.clone(), plan);
    let handles = submit_all(&run, specs);
    let streams = run_session(&run, &handles);

    for (i, (stream, twin_out)) in streams.iter().zip(&twin_outs).enumerate() {
        assert_eq!(
            n_finished(stream),
            1,
            "req {}: every request terminates with exactly one Finished",
            i + 1
        );
        let out = finished_of(stream).unwrap();
        if out.finish == FinishReason::Error {
            assert!(
                twin_out.tokens.starts_with(&out.tokens),
                "req {}: a failed request keeps a bit-identical token prefix \
                 (got {:?}, twin {:?})",
                i + 1,
                out.tokens,
                twin_out.tokens
            );
        } else {
            assert_eq!(
                out.tokens,
                twin_out.tokens,
                "req {}: survivor must be bit-identical to the fault-free twin",
                i + 1
            );
            assert_eq!(out.finish, twin_out.finish, "req {}", i + 1);
        }
    }
    assert_eq!(
        run.with_scheduler(|s| s.arena().used()),
        0,
        "the faulted arena must drain to zero"
    );
    assert_eq!(
        run.with_scheduler(|s| s.swap_pool().used_bytes()),
        0,
        "no swap bytes stranded"
    );
    (run, streams, twin_outs)
}

fn ample_specs(seed: u64) -> Vec<Spec> {
    let mut rng = Pcg32::new(seed);
    vec![
        (rand_prompt(&mut rng, 33), 12, 16, "paged"),
        (rand_prompt(&mut rng, 48), 9, 24, "streaming"),
        (rand_prompt(&mut rng, 21), 15, 16, "inverse_key_norm"),
        (rand_prompt(&mut rng, 27), 11, 16, "keydiff"),
    ]
}

/// Two long requests that cannot both fit 36 blocks: the forced-
/// preemption workload the nosnap/norestore classes need.
fn pressure_specs(seed: u64) -> Vec<Spec> {
    let mut rng = Pcg32::new(seed);
    vec![
        (rand_prompt(&mut rng, 64), 24, 16, "full"),
        (rand_prompt(&mut rng, 64), 24, 16, "full"),
    ]
}

/// MATRIX CLASS 1 — transient decode error: recovered by suspend-and-
/// retry, the survivor is bit-identical and the retry is accounted.
#[test]
fn matrix_transient_decode_error_recovers_bit_identical() {
    let (run, streams, _) = run_twinned(
        &cfg(4, 4, 10_000),
        FaultPlan::new().transient_at(2, 3),
        &ample_specs(42),
    );
    let counts = run.with_scheduler(|s| s.backend().fault_counts());
    assert_eq!(counts.transient, 1, "exactly the scripted fault fired");
    assert_eq!(run.with_scheduler(|s| s.fault_retries), 1);
    assert_eq!(run.with_scheduler(|s| s.quarantined), 0);
    let out = finished_of(&streams[1]).unwrap();
    assert_eq!(out.retries, 1, "the retry surfaces on the request output");
    assert!(
        streams[1].iter().any(|e| matches!(e, SeqEvent::Preempted { .. })),
        "a retry rides the preemption machinery (and its events)"
    );
}

/// MATRIX CLASS 2 — terminal decode error: that request retires as
/// `Error` keeping its partial tokens; everyone else is untouched.
#[test]
fn matrix_terminal_decode_error_fails_one_request_cleanly() {
    let (run, streams, twin_outs) = run_twinned(
        &cfg(4, 4, 10_000),
        FaultPlan::new().terminal_at(3, 2),
        &ample_specs(43),
    );
    let counts = run.with_scheduler(|s| s.backend().fault_counts());
    assert_eq!(counts.terminal, 1);
    let out = finished_of(&streams[2]).unwrap();
    assert_eq!(out.finish, FinishReason::Error, "lane 3 dies terminally");
    assert_eq!(
        out.tokens.len(),
        2,
        "prefill token + decode attempt 1 survive; attempt 2 killed it"
    );
    assert_eq!(
        run.with_scheduler(|s| s.quarantined),
        0,
        "a terminal backend error is not a quarantine"
    );
    // the other three all completed normally
    for (i, s) in streams.iter().enumerate() {
        if i != 2 {
            assert_eq!(finished_of(s).unwrap().finish, twin_outs[i].finish);
        }
    }
}

/// MATRIX CLASS 3 — whole-batch failure: every running sequence errors
/// at once, every one retries, all outputs stay bit-identical.
#[test]
fn matrix_whole_batch_failure_retries_everyone_losslessly() {
    let (run, _, _) = run_twinned(
        &cfg(4, 4, 10_000),
        FaultPlan::new().batch_fail_at(3),
        &ample_specs(44),
    );
    let counts = run.with_scheduler(|s| s.backend().fault_counts());
    assert_eq!(counts.batch_failures, 1);
    assert_eq!(
        run.with_scheduler(|s| s.fault_retries),
        4,
        "all four running sequences retried the failed round"
    );
    assert_eq!(run.with_scheduler(|s| s.quarantined), 0);
}

/// MATRIX CLASS 4 — snapshot refusal under memory pressure: every
/// preemption victim is forced down the recompute path, which must be
/// bit-identical to the twin's swap-restore path.
#[test]
fn matrix_snapshot_refusal_forces_bit_identical_recompute() {
    let config = SchedConfig { swap_bytes: 16 << 20, ..cfg(4, 2, 36) };
    let (run, _, _) = run_twinned(
        &config,
        FaultPlan::new().refuse_snapshots(),
        &pressure_specs(45),
    );
    let counts = run.with_scheduler(|s| s.backend().fault_counts());
    assert!(
        counts.snapshot_refusals >= 1,
        "36 blocks force preemption, so the refusal must fire"
    );
    let (swap_outs, preemptions) = run.with_scheduler(|s| (s.swap_outs, s.preemptions));
    assert_eq!(swap_outs, 0, "nothing can park: every victim recomputes");
    assert!(preemptions >= 1);
}

/// MATRIX CLASS 5 — restore failure: the parked snapshot's restore
/// errors, the scheduler falls back to recompute-and-replay, outputs
/// stay bit-identical and no swap bytes strand.
#[test]
fn matrix_restore_failure_falls_back_to_recompute() {
    let config = SchedConfig { swap_bytes: 16 << 20, ..cfg(4, 2, 36) };
    let (run, _, _) = run_twinned(
        &config,
        FaultPlan::new().fail_restores(2),
        &pressure_specs(46),
    );
    let (counts, swap_outs) =
        run.with_scheduler(|s| (s.backend().fault_counts(), s.swap_outs));
    assert!(swap_outs >= 1, "victims must actually park for restores to fail");
    assert!(counts.restore_failures >= 1, "the injected restore failure fired");
}

/// Retry budget exhaustion: with a zero budget the FIRST transient error
/// quarantines the request as `Error` instead of retrying.
#[test]
fn retry_budget_exhaustion_quarantines_as_error() {
    let config = SchedConfig { max_transient_retries: 0, ..cfg(4, 2, 10_000) };
    let specs = ample_specs(47)[..2].to_vec();
    let (run, streams, _) =
        run_twinned(&config, FaultPlan::new().transient_at(1, 2), &specs);
    let out = finished_of(&streams[0]).unwrap();
    assert_eq!(out.finish, FinishReason::Error);
    assert_eq!(out.retries, 0, "no budget means no retries were consumed");
    assert_eq!(run.with_scheduler(|s| s.quarantined), 1);
    assert_eq!(run.with_scheduler(|s| s.fault_retries), 0);
}

/// Circuit breaker: a poison request whose decode fails on EVERY attempt
/// keeps its lane across swap restores, so the consecutive-failure streak
/// accumulates across suspensions and quarantines it with retry budget
/// to spare — instead of grinding the batch forever.
#[test]
fn circuit_breaker_quarantines_poison_request_across_swap_restores() {
    let config = SchedConfig { swap_bytes: 16 << 20, ..cfg(4, 2, 10_000) };
    let specs = ample_specs(48)[..1].to_vec();
    let (run, streams, _) =
        run_twinned(&config, FaultPlan::new().transient_from(1, 2), &specs);
    let out = finished_of(&streams[0]).unwrap();
    assert_eq!(out.finish, FinishReason::Error);
    assert_eq!(
        out.tokens.len(),
        2,
        "prefill + one clean decode attempt survive the quarantine"
    );
    // streak limit 4: failures at attempts 2..=5, the first three retry
    // (each a park + restore), the fourth trips the breaker
    assert_eq!(out.retries, 3, "breaker fired with retry budget (8) to spare");
    assert_eq!(out.swaps, 3, "each retry parked and restored a snapshot");
    assert_eq!(run.with_scheduler(|s| (s.fault_retries, s.quarantined)), (3, 1));
    assert_eq!(run.with_scheduler(|s| s.backend().fault_counts()).transient, 4);
}

/// The recompute escape hatch: with swap disabled a retry re-prefills and
/// gets a FRESH lane — exactly like a brand-new request to the backend —
/// so a per-lane persistent fault clears and the request completes
/// bit-identically. (The breaker above is for faults that follow the
/// request; this is for faults that follow the backend slot.)
#[test]
fn transient_recovery_via_recompute_gets_a_fresh_lane() {
    let specs = ample_specs(49)[..1].to_vec();
    let (run, streams, twin_outs) =
        run_twinned(&cfg(4, 2, 10_000), FaultPlan::new().transient_from(1, 2), &specs);
    let out = finished_of(&streams[0]).unwrap();
    assert_eq!(out.finish, twin_outs[0].finish, "the request fully recovers");
    assert_eq!(out.tokens, twin_outs[0].tokens);
    assert_eq!(out.retries, 1, "one retry, then the fresh lane runs clean");
    assert_eq!(run.with_scheduler(|s| (s.fault_retries, s.quarantined)), (1, 0));
}

/// SATELLITE (twin-run property): a terminally-failed request releases
/// its arena pages EXACTLY — shared prefix pages a live sharer holds
/// survive by refcount, and after the failure the arena matches a twin
/// run in which the failed request never existed.
#[test]
fn terminal_failure_releases_shared_prefix_pages_exactly() {
    let page = 4;
    let mut rng = Pcg32::new(50);
    let prefix = rand_prompt(&mut rng, 4 * page);
    let mut pa = prefix.clone();
    pa.extend(rand_prompt(&mut rng, 12));
    let mut pb = prefix;
    pb.extend(rand_prompt(&mut rng, 12));
    let mk_cfg = || SchedConfig { prefix_cache: true, ..cfg(page, 4, 4096) };
    let submit = |s: &FaultySession, p: &[u32]| {
        s.submit(
            RequestBuilder::new(p.to_vec())
                .max_new_tokens(16)
                .budget(1024)
                .policy("full"),
        )
        .unwrap()
    };

    // twin: A alone
    let twin = Session::new_sim_faulty(mk_cfg(), FaultPlan::new());
    let ha2 = submit(&twin, &pa);
    // real run: A + B sharing the 4-page prefix; B (lane 2) dies at
    // decode attempt 4
    let run = Session::new_sim_faulty(mk_cfg(), FaultPlan::new().terminal_at(2, 4));
    let ha1 = submit(&run, &pa);
    run.step().unwrap(); // A admitted, prefix published
    twin.step().unwrap();
    let hb = submit(&run, &pb);
    let mut b_events: Vec<SeqEvent> = Vec::new();
    for _ in 0..40 {
        run.step().unwrap();
        twin.step().unwrap();
        b_events.extend(hb.drain());
        if b_events.iter().any(|e| matches!(e, SeqEvent::Finished(_))) {
            break;
        }
    }
    let hits = run.with_scheduler(|s| s.prefix_hit_blocks);
    assert!(hits >= 4, "B must map the shared prefix (got {hits} hits)");
    assert_eq!(n_finished(&b_events), 1, "exactly one Finished for the failure");
    let out_b = finished_of(&b_events).unwrap();
    assert_eq!(out_b.finish, FinishReason::Error);
    assert_eq!(run.with_scheduler(|s| s.backend().fault_counts()).terminal, 1);
    // the exact-reclaim property: with B dead, the arena must look as if
    // B never existed — its private pages freed, the shared prefix pages
    // A holds still resident (a bad refcount free would panic or leak)
    let used_run = run.with_scheduler(|s| s.arena().used());
    let used_twin = twin.with_scheduler(|s| s.arena().used());
    assert_eq!(used_run, used_twin, "terminal failure must release B exactly");
    assert!(used_twin > 0, "A is still mid-decode on live pages");

    run.run_until_idle().unwrap();
    twin.run_until_idle().unwrap();
    let toks = |h: &FaultyHandle| finished_of(&h.drain()).map(|o| o.tokens);
    assert_eq!(toks(&ha1), toks(&ha2), "the sharer's output is untouched");
    assert_eq!(run.with_scheduler(|s| s.arena().used()), 0);
    assert_eq!(twin.with_scheduler(|s| s.arena().used()), 0);
}

/// SATELLITE (swap leg): a request that parked in the swap pool, was
/// restored (keeping its fault lane) and THEN died terminally strands
/// nothing — swap pool empty, arena drained, survivor bit-identical.
#[test]
fn terminal_failure_after_swap_restore_drains_the_swap_pool() {
    let page = 4;
    let gen = 24;
    let mut rng = Pcg32::new(51);
    let pa = rand_prompt(&mut rng, 64);
    let pb = rand_prompt(&mut rng, 64);
    let want_a = {
        let mut s = Scheduler::new_sim(cfg(page, 1, 10_000));
        let mut r = Request::new(1, pa.clone(), gen);
        r.budget = 16;
        r.policy = "full".into();
        s.submit(r);
        s.run_to_completion().unwrap().pop().unwrap().tokens
    };

    let session = Session::new_sim_faulty(
        SchedConfig { swap_bytes: 16 << 20, ..cfg(page, 2, 36) },
        // B (lane 2) is preempted early — 36 blocks cannot hold both —
        // and survives its park until decode attempt 12 kills it
        FaultPlan::new().terminal_from(2, 12),
    );
    let submit = |p: Vec<u32>| {
        session
            .submit(RequestBuilder::new(p).max_new_tokens(gen).budget(16).policy("full"))
            .unwrap()
    };
    let ha = submit(pa);
    let hb = submit(pb);
    let streams = run_session(&session, &[ha, hb]);

    assert_eq!(n_finished(&streams[1]), 1);
    let out_b = finished_of(&streams[1]).unwrap();
    assert_eq!(out_b.finish, FinishReason::Error);
    assert!(
        out_b.swaps >= 1,
        "B must have parked and restored before dying (got {} swaps)",
        out_b.swaps
    );
    assert!(session.with_scheduler(|s| s.backend().fault_counts()).terminal >= 1);
    assert_eq!(
        session.with_scheduler(|s| s.swap_pool().used_bytes()),
        0,
        "the dead request's swap bytes are reclaimed"
    );
    assert_eq!(session.with_scheduler(|s| s.arena().used()), 0);
    let out_a = finished_of(&streams[0]).unwrap();
    assert_eq!(out_a.tokens, want_a, "survivor output bit-identical");
}

/// Seeded chaos sweep: probabilistic transient faults across a batch.
/// Whatever the (deterministic) schedule injects, every request
/// terminates exactly once, survivors are bit-identical to the twin and
/// the arena drains — the universal invariants under arbitrary chaos.
#[test]
fn seeded_chaos_sweep_holds_the_universal_invariants() {
    let mut rng = Pcg32::new(52);
    let specs: Vec<Spec> = (0..6)
        .map(|i| {
            (
                rand_prompt(&mut rng, 16 + 4 * i),
                10 + i,
                16,
                ["paged", "streaming", "full"][i % 3],
            )
        })
        .collect();
    let (run, _, _) = run_twinned(
        &cfg(4, 6, 10_000),
        FaultPlan::new().seeded(11).p_transient(150),
        &specs,
    );
    let counts = run.with_scheduler(|s| s.backend().fault_counts());
    assert!(
        counts.transient >= 3,
        "150 permille over ~70 attempts must inject (got {})",
        counts.transient
    );
    assert!(run.with_scheduler(|s| s.fault_retries) >= 1);
}
