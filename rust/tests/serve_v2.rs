//! v2 NDJSON wire protocol over REAL TCP against the sim-backend engine
//! loop — no PJRT, plain tier-1 `cargo test`: streaming submits with
//! per-token event lines, one-shot submits, legacy v1 lines, server-
//! assigned id uniqueness across raced connections, and aborts (mid-
//! stream from a second connection; unknown/finished ids as clean
//! no-ops).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use paged_eviction::scheduler::SchedConfig;
use paged_eviction::server::serve::{serve_forever, spawn_sim_engine, ServeOpts};
use paged_eviction::util::json::Json;

fn cfg() -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: 4,
        max_concurrency: 4,
        max_live_blocks: 4096,
        ..SchedConfig::default()
    }
}

fn start_server() -> std::net::SocketAddr {
    let (handle, _join) = spawn_sim_engine(cfg()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = serve_forever(listener, handle, ServeOpts::default());
    });
    addr
}

struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let w = stream.try_clone().unwrap();
        Client { w, r: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.w, "{line}").unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection closed unexpectedly");
        Json::parse(line.trim()).unwrap()
    }
}

fn event_of(j: &Json) -> Option<&str> {
    j.get("event").and_then(|v| v.as_str())
}

#[test]
fn streaming_submit_emits_accepted_prefilled_tokens_finished() {
    let mut c = Client::connect(start_server());
    c.send(r#"{"op": "submit", "prompt": [1,2,3,4], "max_new_tokens": 5, "stream": true}"#);
    let j = c.recv();
    assert_eq!(event_of(&j), Some("accepted"));
    let id = j.get("id").unwrap().as_usize().unwrap();
    assert!(id >= 1, "server-assigned ids start at 1");

    let j = c.recv();
    assert_eq!(event_of(&j), Some("prefilled"), "stream opens with prefilled");
    assert!(j.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    let mut toks: Vec<usize> = Vec::new();
    loop {
        let j = c.recv();
        match event_of(&j).unwrap() {
            "token" => {
                assert_eq!(j.get("id").unwrap().as_usize(), Some(id));
                assert_eq!(j.get("step").unwrap().as_usize(), Some(toks.len()));
                toks.push(j.get("tok").unwrap().as_usize().unwrap());
            }
            "finished" => {
                let fin: Vec<usize> = j
                    .get("tokens")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_usize().unwrap())
                    .collect();
                assert_eq!(toks, fin, "streamed tokens ARE the final output");
                assert_eq!(toks.len(), 5);
                assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
}

#[test]
fn one_shot_and_legacy_lines_coexist() {
    let mut c = Client::connect(start_server());
    // v2 one-shot: accepted ack, then the legacy-format response line
    c.send(r#"{"op": "submit", "prompt": [9,8,7], "max_new_tokens": 3, "stream": false}"#);
    let j = c.recv();
    assert_eq!(event_of(&j), Some("accepted"));
    let id = j.get("id").unwrap().as_usize().unwrap();
    let j = c.recv();
    assert_eq!(event_of(&j), None, "one-shot response is the bare v1 shape");
    assert_eq!(j.get("id").unwrap().as_usize(), Some(id));
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);

    // v1 line with a caller id: echoed back
    c.send(r#"{"id": 55, "prompt": [1,2,3,4], "max_new_tokens": 2}"#);
    let j = c.recv();
    assert_eq!(j.get("id").unwrap().as_usize(), Some(55));
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));

    // v1 auto-id and malformed lines
    c.send(r#"{"text": "hello", "max_new_tokens": 2}"#);
    let j = c.recv();
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    c.send("not json");
    let j = c.recv();
    assert!(j.get("error").is_some(), "malformed line gets an error object");
    // bad policy on a v1 line: the v1 contract is a RESPONSE carrying the
    // caller's id with finish "error", not an id-less error object
    c.send(r#"{"id": 42, "prompt": [1,2], "policy": "quantum"}"#);
    let j = c.recv();
    assert_eq!(j.get("id").unwrap().as_usize(), Some(42));
    assert_eq!(j.get("finish").unwrap().as_str(), Some("error"));
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 0);
    c.send(r#"{"prompt": [1,2], "max_new_tokens": 1}"#);
    assert_eq!(c.recv().get("tokens").unwrap().as_arr().unwrap().len(), 1);
}

#[test]
fn server_assigned_ids_unique_across_connections() {
    let addr = start_server();
    let mut ids = std::collections::HashSet::new();
    for _ in 0..3 {
        let mut c = Client::connect(addr);
        for _ in 0..2 {
            c.send(r#"{"op": "submit", "prompt": [1,2,3], "max_new_tokens": 1, "stream": false}"#);
            let j = c.recv();
            assert_eq!(event_of(&j), Some("accepted"));
            assert!(
                ids.insert(j.get("id").unwrap().as_usize().unwrap()),
                "server-assigned ids must never collide"
            );
            let _ = c.recv(); // one-shot response
        }
    }
    assert_eq!(ids.len(), 6);
}

/// SATELLITE: mid-stream abort from a second connection — the aborted
/// stream ends with the server's `aborted` notice and NO `finished`
/// event; aborting unknown/finished ids is a clean no-op error.
#[test]
fn abort_mid_stream_and_unknown_id_noop() {
    let addr = start_server();
    let mut streamer = Client::connect(addr);
    // effectively endless generation so the abort always lands mid-run
    let submit = concat!(
        r#"{"op": "submit", "prompt": [1,2,3,4,5,6,7,8], "#,
        r#""max_new_tokens": 1000000, "budget": 64, "stream": true}"#
    );
    streamer.send(submit);
    let j = streamer.recv();
    assert_eq!(event_of(&j), Some("accepted"));
    let id = j.get("id").unwrap().as_usize().unwrap();

    // consume the stream concurrently (no backpressure — the engine
    // stall-cancels sinks that fall EVENT_CHANNEL_CAP behind), signalling
    // the first token so the abort provably lands mid-decode
    let (tok_tx, tok_rx) = std::sync::mpsc::channel();
    let reader = std::thread::spawn(move || {
        let mut kinds: Vec<String> = Vec::new();
        loop {
            let j = streamer.recv();
            let kind = event_of(&j).expect("event line").to_string();
            if kind == "token" {
                let _ = tok_tx.send(());
            }
            if kind == "aborted" {
                assert_eq!(j.get("id").unwrap().as_usize(), Some(id));
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
            }
            let stop = kind == "aborted" || kind == "finished";
            kinds.push(kind);
            if stop {
                break;
            }
        }
        kinds
    });

    let mut ctl = Client::connect(addr);
    // unknown id first: clean no-op error, server keeps running
    ctl.send(r#"{"op": "abort", "id": 999999}"#);
    let j = ctl.recv();
    assert_eq!(event_of(&j), Some("aborted"));
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    assert!(j.get("error").is_some());

    // abort strictly mid-decode: after the first streamed token
    tok_rx.recv().expect("the stream must produce tokens");
    ctl.send(&format!(r#"{{"op": "abort", "id": {id}}}"#));
    let j = ctl.recv();
    assert_eq!(event_of(&j), Some("aborted"));
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));

    // aborting the SAME id again: it is gone — clean no-op
    ctl.send(&format!(r#"{{"op": "abort", "id": {id}}}"#));
    assert_eq!(ctl.recv().get("ok").unwrap().as_bool(), Some(false));

    let kinds = reader.join().unwrap();
    assert!(kinds.iter().any(|k| k == "token"), "tokens streamed before the abort");
    assert!(
        kinds.iter().all(|k| k != "finished"),
        "an aborted request must emit no finished event"
    );
    assert_eq!(kinds.last().map(String::as_str), Some("aborted"));

    // server is still healthy for new work
    let mut c = Client::connect(addr);
    c.send(r#"{"op": "submit", "prompt": [4,5,6], "max_new_tokens": 2, "stream": false}"#);
    assert_eq!(event_of(&c.recv()), Some("accepted"));
    assert_eq!(c.recv().get("tokens").unwrap().as_arr().unwrap().len(), 2);
}
