//! SLO workload engine + chunked prefill, end to end on the sim backend.
//!
//! Pins the PR 8 contracts:
//!   * arrival-process and scenario synthesis are pure functions of the
//!     seed (byte-identical replays) with sane interarrival statistics;
//!   * chunked prefill (`SchedConfig::prefill_chunk > 0`) produces output
//!     token streams BIT-IDENTICAL to one-shot prefill — chunking slices
//!     the prefill *compute* across rounds, it never changes what gets
//!     computed — with and without the prefix cache;
//!   * a huge prompt admitted next to active decoders prefills across
//!     many rounds WITHOUT stalling them: decode rounds keep retiring
//!     tokens while the prompt is mid-chunk (the head-of-line-blocking
//!     fix the `slo` driver's long-context scenario leans on).

use paged_eviction::scheduler::{Request, SchedConfig, Scheduler};
use paged_eviction::util::rng::Pcg32;
use paged_eviction::workload::{ArrivalProcess, Scenario};

fn rand_prompt(rng: &mut Pcg32, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(200)).collect()
}

fn cfg(prefill_chunk: usize, prefix_cache: bool) -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: 16,
        max_concurrency: 4,
        max_live_blocks: 10_000,
        prefix_cache,
        prefill_chunk,
        ..SchedConfig::default()
    }
}

// ---- generator determinism + statistics ------------------------------

#[test]
fn arrival_processes_replay_byte_identically() {
    let procs = [
        ArrivalProcess::Poisson { rate: 60.0 },
        ArrivalProcess::Bursty { rate_on: 150.0, rate_off: 4.0, mean_on: 0.1, mean_off: 0.25 },
        ArrivalProcess::Diurnal { base: 8.0, peak: 90.0, period: 3.0 },
    ];
    for p in &procs {
        let a = p.times(&mut Pcg32::new(99), 300);
        let b = p.times(&mut Pcg32::new(99), 300);
        // byte identity, not approximate equality: same seed, same bits
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "{} replay", p.label());
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "{} monotone", p.label());
    }
}

#[test]
fn poisson_interarrivals_match_the_configured_rate() {
    let p = ArrivalProcess::Poisson { rate: 80.0 };
    let times = p.times(&mut Pcg32::new(5), 6000);
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
    // mean interarrival of Poisson(80/s) is 12.5 ms; 6000 samples put the
    // sample mean well within 10%
    assert!(
        (mean_gap - 1.0 / 80.0).abs() < 0.1 / 80.0,
        "mean interarrival {mean_gap} vs expected {}",
        1.0 / 80.0
    );
}

#[test]
fn scenario_synthesis_replays_byte_identically() {
    for name in Scenario::builtin_names() {
        let sc = Scenario::builtin(name).expect("builtin");
        let a = sc.synthesize(1234);
        let b = sc.synthesize(1234);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "{name}: same seed must synthesize a byte-identical trace"
        );
        assert_ne!(a, sc.synthesize(1235), "{name}: seed must matter");
        assert_eq!(a.len(), sc.requests);
        assert!(a.windows(2).all(|w| w[1].at_s >= w[0].at_s), "{name}: arrivals sorted");
    }
}

// ---- chunked prefill: bit-identity ------------------------------------

/// Run a request set through the scheduler and return each request's
/// output tokens (by id) plus the total chunked-prefill advances.
fn run_tokens(
    prefill_chunk: usize,
    prefix_cache: bool,
    reqs: &[(Vec<u32>, usize)],
) -> (Vec<(u64, Vec<u32>)>, u64) {
    let mut sched = Scheduler::new_sim(cfg(prefill_chunk, prefix_cache));
    for (i, (prompt, gen)) in reqs.iter().enumerate() {
        sched.submit(Request::new(i as u64 + 1, prompt.clone(), *gen));
    }
    let mut outs = sched.run_to_completion().expect("run");
    outs.sort_by_key(|o| o.id);
    let toks = outs.into_iter().map(|o| (o.id, o.tokens)).collect();
    (toks, sched.chunk_prefills)
}

#[test]
fn chunked_prefill_is_bit_identical_to_unchunked() {
    // prompts of >= 8 full 16-token blocks, the acceptance bar: 130..=240
    // tokens, deliberately NOT multiples of the chunk so the final
    // partial chunk path runs too
    let mut rng = Pcg32::new(7);
    let reqs: Vec<(Vec<u32>, usize)> = [130usize, 161, 208, 240]
        .iter()
        .map(|&len| (rand_prompt(&mut rng, len), 12))
        .collect();
    for prefix_cache in [false, true] {
        let (plain, chunks_plain) = run_tokens(0, prefix_cache, &reqs);
        let (chunked, chunks) = run_tokens(16, prefix_cache, &reqs);
        assert_eq!(chunks_plain, 0, "prefill_chunk=0 must never chunk");
        assert!(chunks > 0, "prefill_chunk=16 on 130+-token prompts must chunk");
        assert_eq!(
            plain, chunked,
            "chunked prefill changed output tokens (prefix_cache={prefix_cache})"
        );
    }
}

#[test]
fn short_prompts_skip_chunking_entirely() {
    // prompts at or under the chunk go through the classic one-shot path
    let mut rng = Pcg32::new(11);
    let reqs: Vec<(Vec<u32>, usize)> =
        (0..3).map(|_| (rand_prompt(&mut rng, 24), 8)).collect();
    let (plain, _) = run_tokens(0, true, &reqs);
    let (chunked, chunks) = run_tokens(32, true, &reqs);
    assert_eq!(chunks, 0, "24-token prompts under a 32 chunk must not chunk");
    assert_eq!(plain, chunked);
}

// ---- chunked prefill: no head-of-line blocking ------------------------

#[test]
fn huge_prompt_prefills_across_rounds_without_stalling_decoders() {
    let mut sched = Scheduler::new_sim(cfg(16, true));
    let mut rng = Pcg32::new(21);
    // two chat-style decoders get running first
    sched.submit(Request::new(1, rand_prompt(&mut rng, 24), 48));
    sched.submit(Request::new(2, rand_prompt(&mut rng, 24), 48));
    for _ in 0..3 {
        sched.step().expect("warmup round");
    }
    // now a 16-block marathon prompt lands next to them
    sched.submit(Request::new(3, rand_prompt(&mut rng, 256), 8));
    let mut overlap_rounds = 0;
    let mut rounds = 0;
    while !sched.is_idle() {
        let report = sched.step().expect("round");
        // the interleaving the whole feature exists for: the marathon is
        // mid-prefill while this very round still retired decode tokens
        if sched.prefilling() > 0 && report.decoded_tokens > 0 {
            overlap_rounds += 1;
        }
        rounds += 1;
        assert!(rounds < 10_000, "scheduler failed to drain");
    }
    assert!(
        overlap_rounds >= 2,
        "a 256-token prompt at chunk 16 must overlap decode rounds \
         (saw {overlap_rounds} overlapping rounds)"
    );
    let outs = sched.take_finished();
    assert_eq!(outs.len(), 3, "all three requests must finish");
    assert!(sched.chunk_prefills > 0);
}
