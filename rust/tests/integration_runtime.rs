//! End-to-end runtime integration: PJRT loads the AOT artifacts and the
//! full prefill -> pack -> decode pipeline reproduces consistent numerics.
//!
//! Requires the `xla` feature (real PJRT bindings) and `make artifacts`
//! (the test fails with a clear message if the artifacts are missing).
#![cfg(feature = "xla")]

use paged_eviction::eviction::make_policy;
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::util::rng::Pcg32;

fn engine() -> Engine {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(dir).expect("run `make artifacts` before cargo test")
}

fn random_prompt(rng: &mut Pcg32, len: usize, vocab: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(vocab as u32)).collect()
}

#[test]
fn prefill_runs_and_shapes_check() {
    let eng = engine();
    let runner = ModelRunner::new(&eng, "sim-1b", 16).unwrap();
    let mut rng = Pcg32::new(1);
    let prompt = random_prompt(&mut rng, 40, runner.model.vocab_size);
    let (seq, logits) = runner
        .prefill(&prompt, 128, make_policy("full").unwrap())
        .unwrap();
    assert_eq!(logits.len(), runner.model.vocab_size);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert_eq!(seq.cache.live_tokens(), 40);
    assert_eq!(seq.prompt_len, 40);
    seq.cache.check_invariants().unwrap();
}

/// The golden consistency check, now through the FULL Rust stack: stepping
/// the decode graph (paged cache, block tables, masks built by SeqCache)
/// must reproduce the prefill graph's logits for the same prefix.
#[test]
fn decode_steps_match_prefill_logits() {
    let eng = engine();
    let runner = ModelRunner::new(&eng, "sim-1b", 16).unwrap();
    let mut rng = Pcg32::new(2);
    let total = 48usize;
    let start = 40usize;
    let prompt = random_prompt(&mut rng, total, runner.model.vocab_size);

    let (mut seq, mut logits) = runner
        .prefill(&prompt[..start], 1024, make_policy("full").unwrap())
        .unwrap();
    for t in start..total {
        let out = runner.decode_step(&mut seq, prompt[t]).unwrap();
        logits = out.logits;
        let (want_seq, want) = runner
            .prefill(&prompt[..t + 1], 1024, make_policy("full").unwrap())
            .unwrap();
        drop(want_seq);
        let max_diff = logits
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 2e-3, "step {t}: max logits diff {max_diff}");
    }
}

/// Greedy generation under every policy: budgets hold, invariants hold,
/// outputs stay finite, and the cache stats reflect each policy's behaviour.
#[test]
fn generation_under_all_policies() {
    let eng = engine();
    let runner = ModelRunner::new(&eng, "sim-1b", 16).unwrap();
    let budget = 64usize;
    let gen_len = 40usize;
    for policy in ["paged", "streaming", "inverse_key_norm", "keydiff"] {
        let mut rng = Pcg32::new(7);
        let prompt = random_prompt(&mut rng, 100, runner.model.vocab_size);
        let (mut seq, logits) = runner
            .prefill(&prompt, budget, make_policy(policy).unwrap())
            .unwrap();
        assert!(
            seq.cache.live_tokens() <= budget,
            "{policy}: prefill over budget"
        );
        let mut tok = argmax(&logits);
        for _ in 0..gen_len {
            let out = runner.decode_step(&mut seq, tok).unwrap();
            assert!(out.logits.iter().all(|x| x.is_finite()), "{policy}");
            tok = argmax(&out.logits);
            seq.cache.check_invariants().unwrap();
            assert!(
                seq.cache.live_tokens() <= budget + 16,
                "{policy}: live {} >> budget {budget}",
                seq.cache.live_tokens()
            );
        }
        let st = &seq.cache.stats;
        match policy {
            "paged" => {
                assert!(st.blocks_evicted > 0, "paged must evict whole blocks");
                assert_eq!(st.mask_updates, 0, "paged never hole-punches");
                assert_eq!(seq.cache.partial_blocks(), 0);
            }
            "streaming" | "inverse_key_norm" | "keydiff" => {
                assert!(st.mask_updates > 0, "{policy} kills tokens per step");
            }
            _ => {}
        }
    }
}

/// FullCache must grow through bucket migrations and keep numerics sane.
#[test]
fn full_cache_grows_buckets() {
    let eng = engine();
    let runner = ModelRunner::new(&eng, "sim-1b", 16).unwrap();
    let mut rng = Pcg32::new(3);
    // prompt 240 -> initial bucket 256 tokens; decoding 40 more forces a
    // bucket migration past 256.
    let prompt = random_prompt(&mut rng, 240, runner.model.vocab_size);
    let (mut seq, logits) = runner
        .prefill(&prompt, 4096, make_policy("full").unwrap())
        .unwrap();
    let mut tok = argmax(&logits);
    for _ in 0..40 {
        let out = runner.decode_step(&mut seq, tok).unwrap();
        tok = argmax(&out.logits);
    }
    assert_eq!(seq.cache.live_tokens(), 280);
    assert!(seq.cache.stats.bucket_grows >= 1, "expected bucket growth");
    assert_eq!(seq.cache.stats.blocks_evicted, 0);
}

/// Eviction must not corrupt the retained context: after PagedEviction
/// drops a block, continued decoding still matches a from-scratch prefill
/// over exactly the retained tokens. (Numeric regression guard for the
/// table-shuffle path.)
#[test]
fn eviction_preserves_retained_context_numerics() {
    let eng = engine();
    let runner = ModelRunner::new(&eng, "sim-1b", 16).unwrap();
    let mut rng = Pcg32::new(4);
    let vocab = runner.model.vocab_size;
    let prompt = random_prompt(&mut rng, 64, vocab);
    // budget 48 => prefill evicts 16 tokens
    let (seq, _) = runner
        .prefill(&prompt, 48, make_policy("paged").unwrap())
        .unwrap();
    assert_eq!(seq.cache.live_tokens(), 48);
    // Reconstruct the kept positions and check they are ascending + unique.
    let kept: Vec<u32> = seq
        .cache
        .live_token_list()
        .iter()
        .map(|&(_, _, pos, _)| pos)
        .collect();
    let mut sorted = kept.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(kept, sorted, "retained positions must stay ordered");
    assert_eq!(seq.cache.next_position(), 64);
}

#[test]
fn all_three_models_load_and_run() {
    let eng = engine();
    for model in ["sim-1b", "sim-3b", "sim-8b"] {
        let runner = ModelRunner::new(&eng, model, 16).unwrap();
        let mut rng = Pcg32::new(5);
        let prompt = random_prompt(&mut rng, 24, runner.model.vocab_size);
        let (mut seq, logits) = runner
            .prefill(&prompt, 64, make_policy("paged").unwrap())
            .unwrap();
        let mut tok = argmax(&logits);
        for _ in 0..8 {
            let out = runner.decode_step(&mut seq, tok).unwrap();
            tok = argmax(&out.logits);
        }
        assert_eq!(seq.generated.len(), 8, "{model}");
    }
}

/// Page-size ablation artifacts must be loadable and consistent: the same
/// prompt yields identical prefill logits regardless of page size (page
/// size only affects decode-phase granularity).
#[test]
fn page_sizes_agree_on_prefill() {
    let eng = engine();
    let mut rng = Pcg32::new(6);
    let prompt = random_prompt(&mut rng, 32, 256);
    let mut base: Option<Vec<f32>> = None;
    for ps in [8usize, 16, 32] {
        let runner = ModelRunner::new(&eng, "sim-1b", ps).unwrap();
        let (_, logits) = runner
            .prefill(&prompt, 64, make_policy("paged").unwrap())
            .unwrap();
        match &base {
            None => base = Some(logits),
            Some(b) => {
                let d = logits
                    .iter()
                    .zip(b)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(d < 1e-5, "page {ps}: prefill diverged {d}");
            }
        }
    }
}
