//! Hardened-server suite over REAL TCP: graceful shutdown (streaming
//! clients drain to `finished`, new submits get a clean error line, the
//! accept loop and engine thread both exit), deadline-forced shutdown,
//! the concurrent-connection cap, and a ~200-client stress leg mixing
//! well-behaved clients with slow-loris peers, oversized lines and
//! mid-stream disconnects.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use paged_eviction::api::{RequestBuilder, SeqEvent, Session};
use paged_eviction::runtime::FaultPlan;
use paged_eviction::scheduler::SchedConfig;
use paged_eviction::server::serve::{
    serve_until, spawn_sim_engine, spawn_sim_engine_faulty, EngineHandle, ServeOpts,
    ShutdownFlag,
};
use paged_eviction::util::json::Json;

fn cfg() -> SchedConfig {
    SchedConfig {
        model: "sim".into(),
        page_size: 4,
        max_concurrency: 4,
        max_live_blocks: 4096,
        ..SchedConfig::default()
    }
}

struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let w = stream.try_clone().unwrap();
        Client { w, r: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.w, "{line}").unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection closed unexpectedly");
        Json::parse(line.trim()).unwrap()
    }
}

fn event_of(j: &Json) -> Option<&str> {
    j.get("event").and_then(|v| v.as_str())
}

/// Spin up serve_until on its own thread; hand back everything the test
/// needs to drive and later tear it down.
#[allow(clippy::type_complexity)]
fn start(
    handle: EngineHandle,
    opts: ServeOpts,
) -> (std::net::SocketAddr, ShutdownFlag, std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = ShutdownFlag::new();
    let flag = stop.clone();
    let join = std::thread::spawn(move || serve_until(listener, handle, opts, flag));
    (addr, stop, join)
}

/// ACCEPTANCE: graceful shutdown. Streaming clients mid-decode drain to
/// a real `finished` line, a submit during the drain is rejected with a
/// clean error line (never an `accepted`), and after the drain both the
/// accept loop and the engine thread exit.
#[test]
fn graceful_shutdown_drains_streams_rejects_submits_and_exits() {
    // stretch every decode round so the drain window is wide enough to
    // land a mid-drain submit deterministically
    let plan = (1..=400).fold(FaultPlan::new(), |p, call| p.slow_round(call, 3000));
    let (handle, engine_join) = spawn_sim_engine_faulty(cfg(), plan).unwrap();
    let (addr, stop, serve_join) = start(handle.clone(), ServeOpts::default());

    let gen = 80;
    let mut readers = Vec::new();
    for i in 0..3 {
        let (tok_tx, tok_rx) = std::sync::mpsc::channel();
        readers.push((
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                c.send(&format!(
                    r#"{{"op": "submit", "prompt": [{}, 2, 3, 4, 5, 6, 7, 8], "max_new_tokens": {gen}, "stream": true}}"#,
                    i + 1
                ));
                assert_eq!(event_of(&c.recv()), Some("accepted"));
                loop {
                    let j = c.recv();
                    match event_of(&j) {
                        Some("token") => {
                            let _ = tok_tx.send(());
                        }
                        Some("finished") => {
                            return j.get("tokens").unwrap().as_arr().unwrap().len();
                        }
                        Some(_) => {}
                        None => panic!("stream must end in finished, got {j:?}"),
                    }
                }
            }),
            tok_rx,
        ));
    }
    // every stream is provably mid-decode before the shutdown begins
    for (_, rx) in &readers {
        rx.recv_timeout(Duration::from_secs(30)).expect("stream produced a token");
    }

    let shut = {
        let h = handle.clone();
        std::thread::spawn(move || h.shutdown(Duration::from_secs(60)))
    };
    // the drain runs for >= 70 more slowed rounds (~200ms); probe it
    std::thread::sleep(Duration::from_millis(100));
    let mut probe = Client::connect(addr);
    probe.send(r#"{"op": "submit", "prompt": [1, 2, 3], "max_new_tokens": 2, "stream": false}"#);
    let j = probe.recv();
    assert_eq!(event_of(&j), None, "a drain-time submit must never be accepted");
    assert!(j.get("error").is_some(), "rejection is a clean error line: {j:?}");

    assert!(
        shut.join().unwrap().unwrap(),
        "every stream finished on its own: the shutdown drained cleanly"
    );
    for (reader, _) in readers {
        assert_eq!(
            reader.join().unwrap(),
            gen,
            "a drained stream delivers its FULL output, not a truncation"
        );
    }
    // the engine thread is gone; stop the accept loop and it joins too
    engine_join.join().unwrap();
    stop.trigger();
    serve_join.join().unwrap().unwrap();
    assert!(
        TcpStream::connect(addr).is_err(),
        "the listener must be closed once the accept loop exits"
    );
}

/// Deadline-forced shutdown: a request that can never finish is
/// cancelled at the deadline — `shutdown` reports the forced drain, the
/// client's stream ends with an honest error (no fake `finished`), and
/// the engine thread still exits.
#[test]
fn shutdown_deadline_cancels_stragglers_and_reports_it() {
    let (handle, engine_join) = spawn_sim_engine(cfg()).unwrap();
    let (addr, stop, serve_join) = start(handle.clone(), ServeOpts::default());

    let (tok_tx, tok_rx) = std::sync::mpsc::channel();
    let reader = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        c.send(
            r#"{"op": "submit", "prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 1000000, "budget": 64, "stream": true}"#,
        );
        assert_eq!(event_of(&c.recv()), Some("accepted"));
        let mut kinds: Vec<String> = Vec::new();
        loop {
            let j = c.recv();
            if let Some(k) = event_of(&j) {
                if k == "token" {
                    let _ = tok_tx.send(());
                }
                kinds.push(k.to_string());
                if k == "finished" || k == "aborted" {
                    break;
                }
            } else {
                kinds.push("error".into());
                break;
            }
        }
        kinds
    });
    tok_rx.recv_timeout(Duration::from_secs(30)).expect("mid-decode");

    let drained = handle.shutdown(Duration::from_millis(30)).unwrap();
    assert!(!drained, "an endless request cannot drain: the deadline forced it");
    let kinds = reader.join().unwrap();
    assert!(kinds.iter().all(|k| k != "finished"), "no fake finished line");
    assert_eq!(
        kinds.last().map(String::as_str),
        Some("error"),
        "the cut stream ends with an honest error, got {kinds:?}"
    );
    engine_join.join().unwrap();
    stop.trigger();
    serve_join.join().unwrap().unwrap();
}

/// The concurrent-connection cap sheds at accept with a clean error
/// line, and a shed slot is reusable as soon as a connection closes.
#[test]
fn connection_cap_sheds_and_recovers() {
    let (handle, engine_join) = spawn_sim_engine(cfg()).unwrap();
    let opts = ServeOpts { max_connections: 2, ..ServeOpts::default() };
    let (addr, stop, serve_join) = start(handle.clone(), opts);

    let c1 = Client::connect(addr);
    let _c2 = Client::connect(addr);
    // both slots taken (idle but live): the third is shed at accept
    let mut c3 = Client::connect(addr);
    let j = c3.recv();
    assert_eq!(
        j.get("error").and_then(|v| v.as_str()),
        Some("server at connection capacity")
    );
    // freeing a slot frees the cap
    drop(c1);
    std::thread::sleep(Duration::from_millis(200));
    let mut c4 = Client::connect(addr);
    c4.send(r#"{"op": "submit", "prompt": [1, 2, 3], "max_new_tokens": 2, "stream": false}"#);
    assert_eq!(event_of(&c4.recv()), Some("accepted"));
    assert_eq!(c4.recv().get("tokens").unwrap().as_arr().unwrap().len(), 2);

    stop.trigger();
    serve_join.join().unwrap().unwrap();
    handle.shutdown(Duration::from_secs(10)).unwrap();
    engine_join.join().unwrap();
}

/// ACCEPTANCE (stress leg): ~200 concurrent clients — 120 well-behaved,
/// 30 slow-loris trickles, 30 oversized-line floods, 20 mid-stream
/// disconnects. The server sheds every abuser with a clean error line,
/// every well-behaved client completes, and the server is still healthy
/// for new work afterwards.
#[test]
fn stress_200_clients_with_loris_floods_and_disconnects() {
    let (handle, engine_join) = spawn_sim_engine(cfg()).unwrap();
    let opts = ServeOpts {
        read_timeout: Some(Duration::from_millis(250)),
        max_line_bytes: 4096,
        ..ServeOpts::default()
    };
    let (addr, stop, serve_join) = start(handle.clone(), opts);

    let mut threads = Vec::new();
    for i in 0..120u32 {
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            c.send(&format!(
                r#"{{"op": "submit", "prompt": [{}, {}, 3], "max_new_tokens": 3, "stream": false}}"#,
                i % 7 + 1,
                i % 5 + 1
            ));
            assert_eq!(event_of(&c.recv()), Some("accepted"));
            assert_eq!(c.recv().get("tokens").unwrap().as_arr().unwrap().len(), 3);
        }));
    }
    for _ in 0..30 {
        threads.push(std::thread::spawn(move || {
            // slow loris: a partial line and then silence
            let mut c = Client::connect(addr);
            c.w.write_all(b"{\"op\": ").unwrap();
            c.w.flush().unwrap();
            let j = c.recv();
            assert!(
                j.get("error").and_then(|v| v.as_str()).unwrap().contains("timeout"),
                "loris must be disconnected with a clean timeout error: {j:?}"
            );
        }));
    }
    for _ in 0..30 {
        threads.push(std::thread::spawn(move || {
            // a 100 KB line against a 4 KB cap: consumed, never buffered
            let mut c = Client::connect(addr);
            let flood = "x".repeat(100_000);
            // the write may fail midway if the server hangs up first
            let _ = writeln!(c.w, "{{\"pad\": \"{flood}\"}}");
            let _ = c.w.flush();
            let mut line = String::new();
            if c.r.read_line(&mut line).is_ok() && !line.trim().is_empty() {
                let j = Json::parse(line.trim()).unwrap();
                assert!(
                    j.get("error").and_then(|v| v.as_str()).unwrap().contains("exceeds"),
                    "flood must get the oversized-line error: {j:?}"
                );
            }
        }));
    }
    for _ in 0..20 {
        threads.push(std::thread::spawn(move || {
            // vanish mid-stream: the engine must cancel and move on
            let mut c = Client::connect(addr);
            c.send(
                r#"{"op": "submit", "prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 1000000, "budget": 64, "stream": true}"#,
            );
            assert_eq!(event_of(&c.recv()), Some("accepted"));
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    // the server survived all of it and still does real work
    let mut c = Client::connect(addr);
    c.send(r#"{"op": "submit", "prompt": [4, 5, 6], "max_new_tokens": 2, "stream": false}"#);
    assert_eq!(event_of(&c.recv()), Some("accepted"));
    assert_eq!(c.recv().get("tokens").unwrap().as_arr().unwrap().len(), 2);

    stop.trigger();
    serve_join.join().unwrap().unwrap();
    // the vanished clients' cancelled requests drain during shutdown
    handle.shutdown(Duration::from_secs(30)).unwrap();
    engine_join.join().unwrap();
}

/// The `Session::shutdown` API surface itself: draining rejects new
/// submits, completes live work within the deadline, and a zero
/// deadline force-cancels with full arena reclaim.
#[test]
fn session_shutdown_drains_within_deadline_or_cancels() {
    let session = Session::new_sim(cfg());
    let h = session
        .submit(RequestBuilder::new(vec![1, 2, 3, 4]).max_new_tokens(8))
        .unwrap();
    session.step().unwrap();
    assert!(
        session.shutdown(Duration::from_secs(30)).unwrap(),
        "live work drains cleanly inside the deadline"
    );
    assert!(
        session.submit(RequestBuilder::new(vec![1, 2])).is_err(),
        "a draining session rejects new submits"
    );
    assert!(
        h.drain().iter().any(|e| matches!(e, SeqEvent::Finished(_))),
        "the drained request really finished"
    );

    let session = Session::new_sim(cfg());
    let h = session
        .submit(
            RequestBuilder::new(vec![1, 2, 3, 4])
                .max_new_tokens(1_000_000)
                .budget(64),
        )
        .unwrap();
    session.step().unwrap();
    assert!(
        !session.shutdown(Duration::from_millis(0)).unwrap(),
        "an endless request forces cancellation"
    );
    assert!(
        h.drain().iter().all(|e| !matches!(e, SeqEvent::Finished(_))),
        "a force-cancelled request emits no Finished"
    );
    assert_eq!(
        session.with_scheduler(|s| s.arena().used()),
        0,
        "forced shutdown reclaims the arena synchronously"
    );
}
