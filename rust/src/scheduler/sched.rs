//! The continuous-batching scheduler: batched decode rounds over a shared
//! physical block arena, with watermark admission and swap-to-host
//! preemption under memory pressure.
//!
//! Each round:
//!
//!  1. **admission** — fill free concurrency slots from the queue, gated
//!     on the arena's LOW watermark
//!     (`BlockManager::below_low_watermark`, O(1)) against the blocks the
//!     admission claims *immediately*: the policy-aware resident prompt
//!     minus the prompt blocks the prefix index will serve by refcount
//!     for a fresh request, the exact snapshot size for a swapped victim.
//!     Decode-time growth is no longer reserved up front — worst-case
//!     estimates over-reserve precisely when unstructured policies
//!     fragment pages (the paper's Limitation 1); the low/high hysteresis
//!     band absorbs the optimism instead;
//!  2. **watermark preemption** — while usage exceeds the HIGH watermark,
//!     victim-select the **youngest** running sequence and evict it
//!     proactively, before allocation hard-fails;
//!  3. **reservation** — every running sequence that needs a fresh block
//!     for this round's token claims it up front; if the arena still runs
//!     dry, preemption repeats until the round fits;
//!  4. **batched decode** — one `DecodeBackend::decode_batch` call for the
//!     whole running set; finished sequences retire from the results.
//!
//! A preemption victim is parked in a bounded host [`SwapPool`] when the
//! backend can snapshot it (swap-to-host): readmission from the queue
//! front *restores* the snapshot — no prompt recompute, no token replay.
//! When the backend cannot snapshot, the snapshot no longer fits the
//! pool, or the pool LRU-dropped it to make room, the victim falls back
//! to the PR 2 recompute path: the prompt is re-prefilled and the
//! produced tokens are replayed through decode (greedy decode is
//! deterministic, so both paths yield bit-identical outputs).
//!
//! The scheduler is generic over [`DecodeBackend`], so the identical
//! admission/preemption/reservation/retire logic runs on the always-built
//! deterministic sim backend (tier-1 tests) and on the PJRT runner
//! (`--features xla`).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::backend::{DecodeBackend, Prefilled, Restored};
use super::request::{FinishReason, Request, RequestOutput};
use super::swap::SwapPool;
use crate::eviction::make_policy;
use crate::kvcache::{BlockAlloc, BlockManager};
use crate::runtime::model_runner::argmax;
use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub model: String,
    pub page_size: usize,
    /// Max sequences decoded concurrently (vLLM "max_num_seqs").
    pub max_concurrency: usize,
    /// Capacity of the shared physical block arena — the real global KV
    /// memory every sequence allocates from (stands in for GPU memory).
    pub max_live_blocks: usize,
    /// Admission watermark as a fraction of the arena: new work is
    /// admitted only while usage stays at or below it. `1.0` = admit up
    /// to raw capacity.
    pub watermark_low: f64,
    /// Preemption watermark as a fraction of the arena: usage above it
    /// triggers proactive preemption. Must be `>= watermark_low`; the gap
    /// is the hysteresis band that absorbs decode-time growth.
    pub watermark_high: f64,
    /// Byte cap of the host-side swap pool preemption victims are parked
    /// in. `0` disables swap: every victim recomputes on readmission.
    pub swap_bytes: usize,
    /// Automatic prefix caching: prefills publish their full prompt
    /// blocks into the arena's content-hash index and map identical
    /// leading blocks by refcount instead of re-materializing them
    /// (`--prefix-cache on|off`). Greedy outputs are bit-identical either
    /// way — pinned in `tests/prefix_cache.rs` — only the physical
    /// footprint and prefill work change.
    pub prefix_cache: bool,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            model: "sim-1b".into(),
            page_size: 16,
            max_concurrency: 8,
            max_live_blocks: 4096,
            watermark_low: 0.85,
            watermark_high: 0.95,
            swap_bytes: 64 << 20,
            prefix_cache: true,
        }
    }
}

/// What happened during one scheduling round (for traces/benches).
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub prefilled: usize,
    pub decoded_tokens: usize,
    pub finished: usize,
    /// Sequences preempted this round (watermark crossed or arena dry).
    pub preempted: usize,
    /// Sequences readmitted this round by restoring a swap-to-host
    /// snapshot (the `prefilled` count covers recompute readmissions).
    pub swap_restored: usize,
    /// Requests rejected outright (can never fit / bad policy / failed).
    pub rejected: usize,
    /// Prompt blocks this round's prefills mapped from the prefix index
    /// (refcount + 1 on an existing page) instead of allocating.
    pub prefix_hit_blocks: usize,
    /// Copy-on-write page copies made while preparing this round (shared
    /// pages unshared ahead of in-place token kills) by sequences still
    /// running at decode time; copies made by a sequence preempted in the
    /// same round fold into the scheduler-level `cow_copies` aggregate
    /// instead.
    pub cow_copies: usize,
}

/// Queued request plus everything needed to resume it after preemption —
/// by either path: `resume`/`swap_fed` keep the recompute replay valid
/// even while a snapshot is parked in the swap pool, so an LRU-dropped
/// snapshot silently degrades to recompute instead of losing work.
struct QueueEntry {
    req: Request,
    enqueued: Instant,
    /// Tokens produced before preemption, replayed on readmission.
    resume: Vec<u32>,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    preemptions: u32,
    /// Swap-restore readmissions so far.
    swaps: u32,
    /// How many of `resume` were already fed through decode when the
    /// sequence was preempted — the restore point for a swap readmission
    /// (recompute readmissions replay from 0).
    swap_fed: usize,
    /// Pending next token at preemption time, consumed by a swap restore
    /// once `swap_fed == resume.len()` (recompute recomputes it).
    next_token: u32,
}

impl QueueEntry {
    fn fresh(req: Request) -> QueueEntry {
        QueueEntry {
            req,
            enqueued: Instant::now(),
            resume: Vec::new(),
            first_token_at: None,
            decode_seconds: 0.0,
            preemptions: 0,
            swaps: 0,
            swap_fed: 0,
            next_token: 0,
        }
    }
}

/// Book-keeping for an in-flight request.
struct Inflight<S> {
    req: Request,
    seq: S,
    next_token: u32,
    enqueued: Instant,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    /// All tokens produced (including pre-preemption history).
    produced: Vec<u32>,
    /// How many of `produced` have been fed back through decode; while
    /// `fed < produced.len()` the sequence is replaying after preemption.
    fed: usize,
    /// Monotonic admission number — preemption victims are the youngest.
    admit_serial: u64,
    preemptions: u32,
    /// Swap-restore readmissions for this request.
    swaps: u32,
    /// `stats.cow_copies` watermark already folded into the scheduler's
    /// round/aggregate counters (delta accounting across rounds).
    cow_seen: u64,
}

enum AdmitOutcome {
    /// `restored` distinguishes a swap-pool restore from a prefill (fresh
    /// or recompute) for the round report; `hit_blocks` is the prefix-
    /// index hit count of that prefill (0 for restores).
    Admitted { restored: bool, hit_blocks: u64 },
    /// Arena too full right now; entry comes back for a later round.
    OutOfMemory(QueueEntry),
    /// Request failed hard (error output already emitted).
    Failed,
}

pub struct Scheduler<B: DecodeBackend> {
    pub cfg: SchedConfig,
    backend: B,
    arena: BlockManager,
    queue: VecDeque<QueueEntry>,
    running: Vec<Inflight<B::Seq>>,
    finished: Vec<RequestOutput>,
    /// Host-side pool of swapped-out victims (byte-capped LRU).
    swap: SwapPool<B::Snapshot>,
    // aggregate serving metrics
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub decode_step_s: Summary,
    pub total_generated: u64,
    pub total_prompt_tokens: u64,
    /// Total sequences preempted (memory pressure) since start — both
    /// readmission paths.
    pub preemptions: u64,
    /// Preemption victims successfully parked in the swap pool.
    pub swap_outs: u64,
    /// Readmissions served by restoring a snapshot (no recompute).
    pub swap_restores: u64,
    /// Total prompt blocks served from the prefix index across all
    /// prefills (including recompute readmissions — those hits are real
    /// arena events too).
    pub prefix_hit_blocks: u64,
    /// Total copy-on-write page copies made during round preparation.
    pub cow_copies: u64,
    started: Option<Instant>,
    admit_counter: u64,
}

impl<B: DecodeBackend> Scheduler<B> {
    /// Build a scheduler around an existing backend. The shared arena is
    /// sized by `cfg.max_live_blocks` with the configured admission /
    /// preemption watermark band.
    pub fn with_backend(mut backend: B, cfg: SchedConfig) -> Self {
        let arena = BlockManager::new(cfg.max_live_blocks);
        arena.set_watermarks(cfg.watermark_low, cfg.watermark_high);
        backend.set_prefix_cache(cfg.prefix_cache);
        let swap = SwapPool::new(cfg.swap_bytes);
        Scheduler {
            cfg,
            backend,
            arena,
            queue: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            swap,
            ttft: Histogram::new(),
            tpot: Histogram::new(),
            decode_step_s: Summary::new(),
            total_generated: 0,
            total_prompt_tokens: 0,
            preemptions: 0,
            swap_outs: 0,
            swap_restores: 0,
            prefix_hit_blocks: 0,
            cow_copies: 0,
            started: None,
            admit_counter: 0,
        }
    }

    /// The shared physical block arena (O(1) global accounting).
    pub fn arena(&self) -> &BlockManager {
        &self.arena
    }

    /// The host-side swap pool (byte accounting, LRU drop count).
    pub fn swap_pool(&self) -> &SwapPool<B::Snapshot> {
        &self.swap
    }

    pub fn submit(&mut self, mut req: Request) {
        if req.budget == 0 {
            // A zero-token cache cannot hold even the incoming token; the
            // old code silently floored this to 2 blocks. Reject it.
            log::warn!("req {}: zero cache budget — rejected", req.id);
            self.finished.push(Self::error_output(&req));
            return;
        }
        if req.budget < self.cfg.page_size {
            // Sub-page budgets are clamped up: one page is the smallest
            // unit the paged layout can serve.
            log::debug!(
                "req {}: budget {} below page size {} — clamped",
                req.id,
                req.budget,
                self.cfg.page_size
            );
            req.budget = self.cfg.page_size;
        }
        self.queue.push_back(QueueEntry::fresh(req));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Allocated blocks across ALL sequences — O(1) from the arena, not a
    /// scan over running sequences.
    pub fn live_blocks(&self) -> usize {
        self.arena.used()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// Drain all completed outputs accumulated so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    fn error_output(req: &Request) -> RequestOutput {
        RequestOutput {
            id: req.id,
            tokens: Vec::new(),
            finish: FinishReason::Error,
            ttft_s: 0.0,
            tpot_s: 0.0,
            prompt_len: req.prompt.len(),
            live_cache_tokens: 0,
            preemptions: 0,
            swaps: 0,
            cache_stats: Default::default(),
        }
    }

    /// One scheduling round: admit, reserve (preempting under pressure),
    /// one batched decode for the whole running set, retire finished.
    pub fn step(&mut self) -> Result<StepReport> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let mut report = StepReport::default();

        // --- admission: fill every free concurrency slot, gated on the
        // arena's low watermark against what the admission claims NOW:
        // the policy-aware resident prompt MINUS the blocks the prefix
        // index will serve by refcount (`DecodeBackend::prefill_claim` —
        // cached blocks are pinned, not re-claimed), or a swapped
        // victim's exact snapshot size. Worst-case decode growth is never
        // reserved: the low/high hysteresis band absorbs it and
        // preemption above the high mark reclaims it (the old worst-case
        // gate over-reserved exactly when unstructured policies fragment
        // pages — the paper's Limitation 1) ---
        while self.running.len() < self.cfg.max_concurrency {
            let Some(entry) = self.queue.pop_front() else { break };
            let incoming = self.swap.arena_blocks_of(entry.req.id).unwrap_or_else(|| {
                self.backend.prefill_claim(&self.arena, &entry.req, self.cfg.page_size)
            });
            // With nothing running the gate is bypassed: no sequence can
            // ever free blocks, so either the admission fits the raw
            // capacity now or the request can never run (rejected below
            // when its prefill runs the arena dry).
            if !self.arena.below_low_watermark(incoming) && !self.running.is_empty() {
                // not enough global KV headroom yet — head-of-line wait
                self.queue.push_front(entry);
                break;
            }
            match self.admit(entry) {
                AdmitOutcome::Admitted { restored, hit_blocks } => {
                    if restored {
                        report.swap_restored += 1;
                    } else {
                        report.prefilled += 1;
                    }
                    report.prefix_hit_blocks += hit_blocks as usize;
                    self.prefix_hit_blocks += hit_blocks;
                }
                AdmitOutcome::OutOfMemory(entry) => {
                    if self.running.is_empty() {
                        // nothing in flight can ever free blocks for it:
                        // the packed prompt simply does not fit the arena
                        log::warn!(
                            "req {}: prefill exceeds the {}-block arena — rejected",
                            entry.req.id,
                            self.arena.capacity()
                        );
                        self.swap.discard(entry.req.id);
                        self.finished.push(Self::error_output(&entry.req));
                        report.rejected += 1;
                        continue;
                    }
                    self.queue.push_front(entry);
                    break;
                }
                AdmitOutcome::Failed => report.rejected += 1,
            }
        }

        // --- high-watermark preemption: reclaim the admission optimism
        // proactively, before allocation hard-fails (the hysteresis
        // partner of the low-mark admission gate) ---
        while self.arena.above_high_watermark() && self.running.len() > 1 {
            let victim = self.youngest_idx();
            self.preempt(victim);
            report.preempted += 1;
        }

        // --- reservation + preemption: every sequence that needs a fresh
        // block for this round claims it now — and every sequence whose
        // policy will hole-punch tokens in place gets its shared prefix
        // pages copied-on-write (`prepare_round`) — so the batched decode
        // below can neither fail on memory nor write a shared page ---
        let mut i = 0;
        while i < self.running.len() {
            let outcome = match self.backend.prepare_round(&mut self.running[i].seq) {
                BlockAlloc::Ready => {
                    B::cache_mut(&mut self.running[i].seq).try_ensure_block()
                }
                blocked => blocked,
            };
            match outcome {
                BlockAlloc::Ready => i += 1,
                BlockAlloc::BucketFull => {
                    if let Err(e) = self.backend.grow_bucket(&mut self.running[i].seq) {
                        log::warn!(
                            "req {}: bucket growth failed: {e:#}",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, true);
                        report.finished += 1;
                    }
                    // retry the same index (grown) or the shifted one
                }
                BlockAlloc::ArenaDry => {
                    if self.running.len() == 1 {
                        // no victim can free memory for this sequence
                        log::warn!(
                            "req {}: arena exhausted with no preemption victim",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, true);
                        report.finished += 1;
                    } else {
                        let victim = self.youngest_idx();
                        self.preempt(victim);
                        report.preempted += 1;
                        i = 0; // indices shifted and capacity freed: rescan
                    }
                }
            }
        }

        // fold this round's copy-on-write work into the report/aggregates
        // (delta against each sequence's last-seen counter)
        for f in self.running.iter_mut() {
            let cow = B::cache(&f.seq).stats.cow_copies;
            report.cow_copies += (cow - f.cow_seen) as usize;
            self.cow_copies += cow - f.cow_seen;
            f.cow_seen = cow;
        }

        // --- batched decode: ONE backend call for the whole running set ---
        if self.running.is_empty() {
            return Ok(report);
        }
        let t0 = Instant::now();
        let toks: Vec<u32> = self
            .running
            .iter()
            .map(|f| if f.fed < f.produced.len() { f.produced[f.fed] } else { f.next_token })
            .collect();
        let mut batch: Vec<(&mut B::Seq, u32)> = self
            .running
            .iter_mut()
            .zip(toks.iter().copied())
            .map(|(f, t)| (&mut f.seq, t))
            .collect();
        let results = self.backend.decode_batch(&mut batch);
        drop(batch);
        let round_s = t0.elapsed().as_secs_f64();
        self.decode_step_s.add(round_s);
        let per_seq_s = round_s / self.running.len() as f64;
        debug_assert_eq!(results.len(), self.running.len(), "backend dropped entries");

        let mut done: Vec<(usize, bool)> = Vec::new();
        for (j, res) in results.into_iter().enumerate() {
            let f = &mut self.running[j];
            let tok = toks[j];
            report.decoded_tokens += 1;
            f.decode_seconds += per_seq_s;
            match res {
                Err(e) => {
                    log::warn!("req {}: decode error: {e:#}", f.req.id);
                    if f.fed >= f.produced.len() {
                        f.produced.push(tok); // retire with what we have
                    }
                    done.push((j, true));
                }
                Ok(logits) => {
                    let replaying = f.fed < f.produced.len();
                    if replaying {
                        f.fed += 1;
                    } else {
                        f.produced.push(tok);
                        f.fed = f.produced.len();
                        self.total_generated += 1;
                    }
                    f.next_token = argmax(&logits);
                    if !replaying {
                        let eos_hit = f.req.eos_token.map_or(false, |e| tok == e);
                        if eos_hit || f.produced.len() >= f.req.max_new_tokens {
                            done.push((j, false));
                        }
                    }
                }
            }
        }
        for &(j, errored) in done.iter().rev() {
            let f = self.running.remove(j);
            self.retire(f, errored);
            report.finished += 1;
        }
        Ok(report)
    }

    /// Run rounds until everything submitted so far is finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    /// Tokens (prompt+generated) per second since the first step — the
    /// paper's throughput metric (§5.1).
    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => {
                (self.total_prompt_tokens + self.total_generated) as f64
                    / t0.elapsed().as_secs_f64()
            }
            None => 0.0,
        }
    }

    fn admit(&mut self, entry: QueueEntry) -> AdmitOutcome {
        // A swapped-out victim readmits by restoring its snapshot: the
        // cache, policy state and model continuation come back exactly as
        // suspended — no prompt recompute, no token replay.
        if let Some(snap) = self.swap.take(entry.req.id) {
            match self.backend.restore(&self.arena, &snap) {
                Ok(Restored::Ready(seq)) => {
                    self.swap_restores += 1;
                    self.admit_counter += 1;
                    let fed = entry.swap_fed.min(entry.resume.len());
                    log::info!(
                        "req {}: restored from swap ({} tokens kept, {} to replay)",
                        entry.req.id,
                        entry.resume.len(),
                        entry.resume.len() - fed
                    );
                    // the snapshot carries the cache's historical CoW
                    // count: seed the delta watermark so it is not
                    // recounted this round
                    let cow_seen = B::cache(&seq).stats.cow_copies;
                    self.running.push(Inflight {
                        next_token: entry.next_token,
                        first_token_at: entry.first_token_at,
                        enqueued: entry.enqueued,
                        decode_seconds: entry.decode_seconds,
                        produced: entry.resume,
                        fed,
                        admit_serial: self.admit_counter,
                        preemptions: entry.preemptions,
                        swaps: entry.swaps + 1,
                        cow_seen,
                        req: entry.req,
                        seq,
                    });
                    return AdmitOutcome::Admitted { restored: true, hit_blocks: 0 };
                }
                Ok(Restored::OutOfMemory) => {
                    // keep the snapshot parked for a later retry
                    self.swap.insert(entry.req.id, snap);
                    return AdmitOutcome::OutOfMemory(entry);
                }
                Err(e) => {
                    log::warn!(
                        "req {}: swap restore failed — falling back to recompute: {e:#}",
                        entry.req.id
                    );
                    // fall through to the prefill + replay path below
                }
            }
        }
        let policy = match make_policy(&entry.req.policy) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("req {}: {e:#}", entry.req.id);
                self.finished.push(Self::error_output(&entry.req));
                return AdmitOutcome::Failed;
            }
        };
        let prefilled = self
            .backend
            .prefill(&self.arena, &entry.req.prompt, entry.req.budget, policy);
        match prefilled {
            Ok(Prefilled::Ready { seq, logits }) => {
                let now = Instant::now();
                if entry.preemptions == 0 {
                    // first admission only: recompute-on-readmission must
                    // not double count useful prompt work (a victim can be
                    // preempted before producing anything, so an empty
                    // resume list does not imply a first admission)
                    self.total_prompt_tokens += entry.req.prompt.len() as u64;
                }
                self.admit_counter += 1;
                // a fresh cache's counters cover exactly this prefill
                let hit_blocks = B::cache(&seq).stats.prefix_hit_blocks;
                let cow_seen = B::cache(&seq).stats.cow_copies;
                self.running.push(Inflight {
                    next_token: argmax(&logits),
                    // The first generated token exists the moment prefill
                    // returns, so TTFT is measured to admission, not to
                    // the end of the first decode step (matches vLLM).
                    // A preempted request keeps its original first-token
                    // time.
                    first_token_at: Some(entry.first_token_at.unwrap_or(now)),
                    enqueued: entry.enqueued,
                    decode_seconds: entry.decode_seconds,
                    produced: entry.resume,
                    fed: 0,
                    admit_serial: self.admit_counter,
                    preemptions: entry.preemptions,
                    swaps: entry.swaps,
                    cow_seen,
                    req: entry.req,
                    seq,
                });
                AdmitOutcome::Admitted { restored: false, hit_blocks }
            }
            Ok(Prefilled::OutOfMemory) => AdmitOutcome::OutOfMemory(entry),
            Err(e) => {
                log::warn!("req {}: prefill failed: {e:#}", entry.req.id);
                self.finished.push(Self::error_output(&entry.req));
                AdmitOutcome::Failed
            }
        }
    }

    /// Index of the most recently admitted running sequence — the
    /// preemption victim (oldest sequences are closest to finishing, so
    /// evicting the youngest wastes the least completed work).
    fn youngest_idx(&self) -> usize {
        self.running
            .iter()
            .enumerate()
            .max_by_key(|(_, f)| f.admit_serial)
            .map(|(i, _)| i)
            .expect("youngest_idx on empty running set")
    }

    /// Evict a running sequence: park its snapshot in the swap pool when
    /// the backend can produce one (swap-to-host), free its blocks, and
    /// requeue it at the queue front. The produced tokens ride along in
    /// the queue entry either way, so a snapshot later LRU-dropped from
    /// the pool degrades to the recompute path without losing work.
    fn preempt(&mut self, idx: usize) {
        let f = self.running.remove(idx);
        self.preemptions += 1;
        let n_blocks = B::cache(&f.seq).n_blocks();
        // fold the victim's not-yet-counted copy-on-write work into the
        // aggregate NOW: the victim misses the post-reservation delta
        // pass, and a later restore re-seeds its watermark from the
        // snapshot (a recompute readmission starts a fresh cache at 0)
        self.cow_copies += B::cache(&f.seq).stats.cow_copies - f.cow_seen;
        let Inflight {
            req,
            seq,
            enqueued,
            first_token_at,
            decode_seconds,
            produced,
            fed,
            preemptions,
            swaps,
            next_token,
            ..
        } = f;
        let mut swapped = false;
        if self.swap.capacity_bytes() > 0 {
            if let Some(snap) = self.backend.snapshot(&seq) {
                swapped = self.swap.insert(req.id, snap);
            }
        }
        if swapped {
            self.swap_outs += 1;
        }
        log::info!(
            "req {}: preempted under memory pressure (freeing {} blocks, {})",
            req.id,
            n_blocks,
            if swapped {
                "snapshot swapped to host"
            } else {
                "produced tokens kept for replay"
            }
        );
        drop(seq); // returns every block the victim held to the arena
        self.queue.push_front(QueueEntry {
            req,
            enqueued,
            resume: produced,
            first_token_at,
            decode_seconds,
            preemptions: preemptions + 1,
            swaps,
            swap_fed: fed,
            next_token,
        });
    }

    fn retire(&mut self, f: Inflight<B::Seq>, errored: bool) {
        let ttft = f
            .first_token_at
            .map(|t| t.duration_since(f.enqueued).as_secs_f64())
            .unwrap_or(0.0);
        let n = f.produced.len();
        let tpot = if n > 1 {
            f.decode_seconds / (n - 1).max(1) as f64
        } else {
            f.decode_seconds
        };
        self.ttft.add(ttft * 1e3);
        self.tpot.add(tpot * 1e3);
        let finish = if errored {
            FinishReason::Error
        } else if f.req.eos_token.is_some() && f.produced.last() == f.req.eos_token.as_ref() {
            FinishReason::Eos
        } else {
            FinishReason::MaxTokens
        };
        let cache = B::cache(&f.seq);
        let live_cache_tokens = cache.live_tokens();
        let mut cache_stats = cache.stats.clone();
        cache_stats.preemptions = f.preemptions as u64;
        cache_stats.swaps = f.swaps as u64;
        cache_stats.peak_arena_blocks = self.arena.stats().peak_used as u64;
        self.finished.push(RequestOutput {
            id: f.req.id,
            tokens: f.produced,
            finish,
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_len: f.req.prompt.len(),
            live_cache_tokens,
            preemptions: f.preemptions,
            swaps: f.swaps,
            cache_stats,
        });
        // f.seq drops here, returning its blocks to the arena
    }
}

impl Scheduler<crate::runtime::SimBackend> {
    /// Scheduler over the always-built deterministic sim backend.
    pub fn new_sim(cfg: SchedConfig) -> Self {
        let backend = crate::runtime::SimBackend::new(cfg.page_size);
        Self::with_backend(backend, cfg)
    }
}

#[cfg(feature = "xla")]
impl<'e> Scheduler<crate::runtime::ModelRunner<'e>> {
    /// Scheduler over the PJRT runtime (historical constructor).
    pub fn new(engine: &'e crate::runtime::Engine, cfg: SchedConfig) -> Result<Self> {
        let runner = crate::runtime::ModelRunner::new(engine, &cfg.model, cfg.page_size)?;
        Ok(Self::with_backend(runner, cfg))
    }
}
