//! The continuous-batching scheduler.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::request::{FinishReason, Inflight, Request, RequestOutput};
use crate::eviction::make_policy;
use crate::runtime::model_runner::argmax;
use crate::runtime::{Engine, ModelRunner};
use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub model: String,
    pub page_size: usize,
    /// Max sequences decoded concurrently (vLLM "max_num_seqs").
    pub max_concurrency: usize,
    /// Global cap on live KV blocks across all sequences — admission gate
    /// (stands in for GPU memory capacity).
    pub max_live_blocks: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            model: "sim-1b".into(),
            page_size: 16,
            max_concurrency: 8,
            max_live_blocks: 4096,
        }
    }
}

/// What happened during one scheduling round (for traces/benches).
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub prefilled: usize,
    pub decoded_tokens: usize,
    pub finished: usize,
}

pub struct Scheduler<'e> {
    pub cfg: SchedConfig,
    runner: ModelRunner<'e>,
    queue: VecDeque<(Request, Instant)>,
    running: Vec<Inflight>,
    finished: Vec<RequestOutput>,
    // aggregate serving metrics
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub decode_step_s: Summary,
    pub total_generated: u64,
    pub total_prompt_tokens: u64,
    started: Option<Instant>,
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine, cfg: SchedConfig) -> Result<Self> {
        let runner = ModelRunner::new(engine, &cfg.model, cfg.page_size)?;
        Ok(Scheduler {
            cfg,
            runner,
            queue: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            ttft: Histogram::new(),
            tpot: Histogram::new(),
            decode_step_s: Summary::new(),
            total_generated: 0,
            total_prompt_tokens: 0,
            started: None,
        })
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn live_blocks(&self) -> usize {
        self.running.iter().map(|f| f.seq.cache.n_blocks()).sum()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// Drain all completed outputs accumulated so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    /// One scheduling round: admit prefills until the concurrency and
    /// global-block budgets are exhausted, then one decode step per running
    /// sequence, retiring finished ones.
    pub fn step(&mut self) -> Result<StepReport> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let mut report = StepReport::default();

        // --- admission: fill every free concurrency slot, gated on
        // capacity. Admitting only one prefill per round (the old
        // behaviour) throttled cold starts head-of-line for no reason:
        // with C free slots and a deep queue it took C rounds — C decode
        // sweeps of every running sequence — to saturate the batch. ---
        while self.running.len() < self.cfg.max_concurrency {
            let Some((req, enq)) = self.queue.pop_front() else { break };
            let needed_blocks =
                (req.budget + 2 * self.cfg.page_size) / self.cfg.page_size;
            if self.live_blocks() + needed_blocks > self.cfg.max_live_blocks {
                // not enough global KV memory — requeue (head-of-line)
                self.queue.push_front((req, enq));
                break;
            }
            match self.admit(req, enq) {
                Ok(()) => report.prefilled += 1,
                Err(e) => log::warn!("prefill failed: {e:#}"),
            }
        }

        // --- decode: one token for every running sequence ---
        let mut i = 0;
        while i < self.running.len() {
            let t0 = Instant::now();
            let fin = self.decode_one(i)?;
            self.decode_step_s.add(t0.elapsed().as_secs_f64());
            report.decoded_tokens += 1;
            if fin {
                let f = self.running.swap_remove(i);
                self.retire(f);
                report.finished += 1;
            } else {
                i += 1;
            }
        }
        Ok(report)
    }

    /// Run rounds until everything submitted so far is finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    /// Tokens (prompt+generated) per second since the first step — the
    /// paper's throughput metric (§5.1).
    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => {
                (self.total_prompt_tokens + self.total_generated) as f64
                    / t0.elapsed().as_secs_f64()
            }
            None => 0.0,
        }
    }

    fn admit(&mut self, req: Request, enqueued: Instant) -> Result<()> {
        let policy = make_policy(&req.policy)?;
        let (seq, logits) = self.runner.prefill(&req.prompt, req.budget, policy)?;
        self.total_prompt_tokens += req.prompt.len() as u64;
        let next = argmax(&logits);
        self.running.push(Inflight {
            req,
            seq,
            next_token: next,
            enqueued,
            first_token_at: None,
            last_token_at: Instant::now(),
            decode_seconds: 0.0,
            produced: Vec::new(),
        });
        Ok(())
    }

    /// Decode one token for running[i]; returns true when finished.
    fn decode_one(&mut self, i: usize) -> Result<bool> {
        let f = &mut self.running[i];
        let tok = f.next_token;
        let t0 = Instant::now();
        let out = match self.runner.decode_step(&mut f.seq, tok) {
            Ok(o) => o,
            Err(e) => {
                log::warn!("req {}: decode error: {e:#}", f.req.id);
                f.produced.push(tok);
                return Ok(true); // retire with what we have
            }
        };
        f.decode_seconds += t0.elapsed().as_secs_f64();
        f.produced.push(tok);
        if f.first_token_at.is_none() {
            f.first_token_at = Some(Instant::now());
        }
        f.last_token_at = Instant::now();
        self.total_generated += 1;
        f.next_token = argmax(&out.logits);
        let eos_hit = f.req.eos_token.map_or(false, |e| tok == e);
        Ok(eos_hit || f.produced.len() >= f.req.max_new_tokens)
    }

    fn retire(&mut self, f: Inflight) {
        let ttft = f
            .first_token_at
            .map(|t| t.duration_since(f.enqueued).as_secs_f64())
            .unwrap_or(0.0);
        let n = f.produced.len();
        let tpot = if n > 1 {
            f.decode_seconds / (n - 1).max(1) as f64
        } else {
            f.decode_seconds
        };
        self.ttft.add(ttft * 1e3);
        self.tpot.add(tpot * 1e3);
        let finish = if f.req.eos_token.is_some()
            && f.produced.last() == f.req.eos_token.as_ref()
        {
            FinishReason::Eos
        } else {
            FinishReason::MaxTokens
        };
        self.finished.push(RequestOutput {
            id: f.req.id,
            tokens: f.produced,
            finish,
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_len: f.req.prompt.len(),
            live_cache_tokens: f.seq.cache.live_tokens(),
            cache_stats: f.seq.cache.stats.clone(),
        });
    }
}
