//! The continuous-batching scheduler: batched decode rounds over a shared
//! physical block arena, with preemption under memory pressure.
//!
//! Each round:
//!
//!  1. **admission** — fill free concurrency slots from the queue, gated
//!     on the REAL arena (`BlockManager::free_count`, O(1)), estimating
//!     `ceil((min(prompt, budget) + max_new_tokens) / page_size)` blocks
//!     per request;
//!  2. **reservation** — every running sequence that needs a fresh block
//!     for this round's token claims it up front; if the arena runs dry,
//!     the scheduler victim-selects the **youngest** running sequence,
//!     frees its blocks and requeues it (recompute-on-readmission);
//!  3. **batched decode** — one `DecodeBackend::decode_batch` call for the
//!     whole running set; finished sequences retire from the results.
//!
//! A preempted request keeps its produced tokens; on readmission the
//! backend re-prefills the prompt and the scheduler *replays* those tokens
//! through the decode path, reconstructing the cache state the original
//! run had (greedy decode is deterministic), then continues generating.
//!
//! The scheduler is generic over [`DecodeBackend`], so the identical
//! admission/reservation/preemption/retire logic runs on the always-built
//! deterministic sim backend (tier-1 tests) and on the PJRT runner
//! (`--features xla`).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::backend::{DecodeBackend, Prefilled};
use super::request::{FinishReason, Request, RequestOutput};
use crate::eviction::make_policy;
use crate::kvcache::{BlockAlloc, BlockManager};
use crate::runtime::model_runner::argmax;
use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub model: String,
    pub page_size: usize,
    /// Max sequences decoded concurrently (vLLM "max_num_seqs").
    pub max_concurrency: usize,
    /// Capacity of the shared physical block arena — the real global KV
    /// memory every sequence allocates from (stands in for GPU memory).
    pub max_live_blocks: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            model: "sim-1b".into(),
            page_size: 16,
            max_concurrency: 8,
            max_live_blocks: 4096,
        }
    }
}

/// What happened during one scheduling round (for traces/benches).
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub prefilled: usize,
    pub decoded_tokens: usize,
    pub finished: usize,
    /// Sequences preempted this round (arena ran dry mid-decode).
    pub preempted: usize,
    /// Requests rejected outright (can never fit / bad policy / failed).
    pub rejected: usize,
}

/// Queued request plus everything needed to resume it after preemption.
struct QueueEntry {
    req: Request,
    enqueued: Instant,
    /// Tokens produced before preemption, replayed on readmission.
    resume: Vec<u32>,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    preemptions: u32,
}

impl QueueEntry {
    fn fresh(req: Request) -> QueueEntry {
        QueueEntry {
            req,
            enqueued: Instant::now(),
            resume: Vec::new(),
            first_token_at: None,
            decode_seconds: 0.0,
            preemptions: 0,
        }
    }
}

/// Book-keeping for an in-flight request.
struct Inflight<S> {
    req: Request,
    seq: S,
    next_token: u32,
    enqueued: Instant,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    /// All tokens produced (including pre-preemption history).
    produced: Vec<u32>,
    /// How many of `produced` have been fed back through decode; while
    /// `fed < produced.len()` the sequence is replaying after preemption.
    fed: usize,
    /// Monotonic admission number — preemption victims are the youngest.
    admit_serial: u64,
    preemptions: u32,
}

enum AdmitOutcome {
    Admitted,
    /// Arena too full right now; entry comes back for a later round.
    OutOfMemory(QueueEntry),
    /// Request failed hard (error output already emitted).
    Failed,
}

pub struct Scheduler<B: DecodeBackend> {
    pub cfg: SchedConfig,
    backend: B,
    arena: BlockManager,
    queue: VecDeque<QueueEntry>,
    running: Vec<Inflight<B::Seq>>,
    finished: Vec<RequestOutput>,
    // aggregate serving metrics
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub decode_step_s: Summary,
    pub total_generated: u64,
    pub total_prompt_tokens: u64,
    /// Total sequences preempted (arena pressure) since start.
    pub preemptions: u64,
    started: Option<Instant>,
    admit_counter: u64,
}

impl<B: DecodeBackend> Scheduler<B> {
    /// Build a scheduler around an existing backend. The shared arena is
    /// sized by `cfg.max_live_blocks`.
    pub fn with_backend(backend: B, cfg: SchedConfig) -> Self {
        let arena = BlockManager::new(cfg.max_live_blocks);
        Scheduler {
            cfg,
            backend,
            arena,
            queue: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            ttft: Histogram::new(),
            tpot: Histogram::new(),
            decode_step_s: Summary::new(),
            total_generated: 0,
            total_prompt_tokens: 0,
            preemptions: 0,
            started: None,
            admit_counter: 0,
        }
    }

    /// The shared physical block arena (O(1) global accounting).
    pub fn arena(&self) -> &BlockManager {
        &self.arena
    }

    pub fn submit(&mut self, mut req: Request) {
        if req.budget == 0 {
            // A zero-token cache cannot hold even the incoming token; the
            // old code silently floored this to 2 blocks. Reject it.
            log::warn!("req {}: zero cache budget — rejected", req.id);
            self.finished.push(Self::error_output(&req));
            return;
        }
        if req.budget < self.cfg.page_size {
            // Sub-page budgets are clamped up: one page is the smallest
            // unit the paged layout can serve.
            log::debug!(
                "req {}: budget {} below page size {} — clamped",
                req.id,
                req.budget,
                self.cfg.page_size
            );
            req.budget = self.cfg.page_size;
        }
        self.queue.push_back(QueueEntry::fresh(req));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Allocated blocks across ALL sequences — O(1) from the arena, not a
    /// scan over running sequences.
    pub fn live_blocks(&self) -> usize {
        self.arena.used()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// Drain all completed outputs accumulated so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    /// Worst-case block need of a request: its prompt can retain at most
    /// `min(prompt, budget)` tokens and generation appends `max_new` more,
    /// ceiling-divided into pages. (Unstructured fragmentation can exceed
    /// this; the reservation pass preempts when it does.)
    fn needed_blocks(req: &Request, page_size: usize) -> usize {
        let tokens = req.prompt.len().min(req.budget) + req.max_new_tokens;
        (tokens + page_size - 1) / page_size
    }

    fn error_output(req: &Request) -> RequestOutput {
        RequestOutput {
            id: req.id,
            tokens: Vec::new(),
            finish: FinishReason::Error,
            ttft_s: 0.0,
            tpot_s: 0.0,
            prompt_len: req.prompt.len(),
            live_cache_tokens: 0,
            preemptions: 0,
            cache_stats: Default::default(),
        }
    }

    /// One scheduling round: admit, reserve (preempting under pressure),
    /// one batched decode for the whole running set, retire finished.
    pub fn step(&mut self) -> Result<StepReport> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let mut report = StepReport::default();

        // --- admission: fill every free concurrency slot, gated on the
        // arena's real free-block count ---
        while self.running.len() < self.cfg.max_concurrency {
            let Some(entry) = self.queue.pop_front() else { break };
            // The estimate is deliberately worst-case; budgeted policies
            // evict during decode and can finish long generations inside a
            // much smaller footprint, so an estimate beyond the whole
            // arena gates on a fully idle arena rather than rejecting.
            // Truly impossible prompts are rejected below, when their
            // prefill runs the arena dry with nothing left to preempt.
            let needed = Self::needed_blocks(&entry.req, self.cfg.page_size)
                .min(self.arena.capacity());
            if needed > self.arena.free_count() {
                // not enough global KV memory yet — head-of-line wait
                self.queue.push_front(entry);
                break;
            }
            match self.admit(entry) {
                AdmitOutcome::Admitted => report.prefilled += 1,
                AdmitOutcome::OutOfMemory(entry) => {
                    if self.running.is_empty() {
                        // nothing in flight can ever free blocks for it:
                        // the packed prompt simply does not fit the arena
                        log::warn!(
                            "req {}: prefill exceeds the {}-block arena — rejected",
                            entry.req.id,
                            self.arena.capacity()
                        );
                        self.finished.push(Self::error_output(&entry.req));
                        report.rejected += 1;
                        continue;
                    }
                    self.queue.push_front(entry);
                    break;
                }
                AdmitOutcome::Failed => report.rejected += 1,
            }
        }

        // --- reservation + preemption: every sequence that needs a fresh
        // block for this round claims it now, so the batched decode below
        // cannot fail on memory ---
        let mut i = 0;
        while i < self.running.len() {
            let outcome = B::cache_mut(&mut self.running[i].seq).try_ensure_block();
            match outcome {
                BlockAlloc::Ready => i += 1,
                BlockAlloc::BucketFull => {
                    if let Err(e) = self.backend.grow_bucket(&mut self.running[i].seq) {
                        log::warn!(
                            "req {}: bucket growth failed: {e:#}",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, true);
                        report.finished += 1;
                    }
                    // retry the same index (grown) or the shifted one
                }
                BlockAlloc::ArenaDry => {
                    if self.running.len() == 1 {
                        // no victim can free memory for this sequence
                        log::warn!(
                            "req {}: arena exhausted with no preemption victim",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, true);
                        report.finished += 1;
                    } else {
                        let victim = self.youngest_idx();
                        self.preempt(victim);
                        report.preempted += 1;
                        i = 0; // indices shifted and capacity freed: rescan
                    }
                }
            }
        }

        // --- batched decode: ONE backend call for the whole running set ---
        if self.running.is_empty() {
            return Ok(report);
        }
        let t0 = Instant::now();
        let toks: Vec<u32> = self
            .running
            .iter()
            .map(|f| if f.fed < f.produced.len() { f.produced[f.fed] } else { f.next_token })
            .collect();
        let mut batch: Vec<(&mut B::Seq, u32)> = self
            .running
            .iter_mut()
            .zip(toks.iter().copied())
            .map(|(f, t)| (&mut f.seq, t))
            .collect();
        let results = self.backend.decode_batch(&mut batch);
        drop(batch);
        let round_s = t0.elapsed().as_secs_f64();
        self.decode_step_s.add(round_s);
        let per_seq_s = round_s / self.running.len() as f64;
        debug_assert_eq!(results.len(), self.running.len(), "backend dropped entries");

        let mut done: Vec<(usize, bool)> = Vec::new();
        for (j, res) in results.into_iter().enumerate() {
            let f = &mut self.running[j];
            let tok = toks[j];
            report.decoded_tokens += 1;
            f.decode_seconds += per_seq_s;
            match res {
                Err(e) => {
                    log::warn!("req {}: decode error: {e:#}", f.req.id);
                    if f.fed >= f.produced.len() {
                        f.produced.push(tok); // retire with what we have
                    }
                    done.push((j, true));
                }
                Ok(logits) => {
                    let replaying = f.fed < f.produced.len();
                    if replaying {
                        f.fed += 1;
                    } else {
                        f.produced.push(tok);
                        f.fed = f.produced.len();
                        self.total_generated += 1;
                    }
                    f.next_token = argmax(&logits);
                    if !replaying {
                        let eos_hit = f.req.eos_token.map_or(false, |e| tok == e);
                        if eos_hit || f.produced.len() >= f.req.max_new_tokens {
                            done.push((j, false));
                        }
                    }
                }
            }
        }
        for &(j, errored) in done.iter().rev() {
            let f = self.running.remove(j);
            self.retire(f, errored);
            report.finished += 1;
        }
        Ok(report)
    }

    /// Run rounds until everything submitted so far is finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    /// Tokens (prompt+generated) per second since the first step — the
    /// paper's throughput metric (§5.1).
    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => {
                (self.total_prompt_tokens + self.total_generated) as f64
                    / t0.elapsed().as_secs_f64()
            }
            None => 0.0,
        }
    }

    fn admit(&mut self, entry: QueueEntry) -> AdmitOutcome {
        let policy = match make_policy(&entry.req.policy) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("req {}: {e:#}", entry.req.id);
                self.finished.push(Self::error_output(&entry.req));
                return AdmitOutcome::Failed;
            }
        };
        let prefilled = self
            .backend
            .prefill(&self.arena, &entry.req.prompt, entry.req.budget, policy);
        match prefilled {
            Ok(Prefilled::Ready { seq, logits }) => {
                let now = Instant::now();
                if entry.preemptions == 0 {
                    // first admission only: recompute-on-readmission must
                    // not double count useful prompt work (a victim can be
                    // preempted before producing anything, so an empty
                    // resume list does not imply a first admission)
                    self.total_prompt_tokens += entry.req.prompt.len() as u64;
                }
                self.admit_counter += 1;
                self.running.push(Inflight {
                    next_token: argmax(&logits),
                    // The first generated token exists the moment prefill
                    // returns, so TTFT is measured to admission, not to
                    // the end of the first decode step (matches vLLM).
                    // A preempted request keeps its original first-token
                    // time.
                    first_token_at: Some(entry.first_token_at.unwrap_or(now)),
                    enqueued: entry.enqueued,
                    decode_seconds: entry.decode_seconds,
                    produced: entry.resume,
                    fed: 0,
                    admit_serial: self.admit_counter,
                    preemptions: entry.preemptions,
                    req: entry.req,
                    seq,
                });
                AdmitOutcome::Admitted
            }
            Ok(Prefilled::OutOfMemory) => AdmitOutcome::OutOfMemory(entry),
            Err(e) => {
                log::warn!("req {}: prefill failed: {e:#}", entry.req.id);
                self.finished.push(Self::error_output(&entry.req));
                AdmitOutcome::Failed
            }
        }
    }

    /// Index of the most recently admitted running sequence — the
    /// preemption victim (oldest sequences are closest to finishing, so
    /// evicting the youngest wastes the least completed work).
    fn youngest_idx(&self) -> usize {
        self.running
            .iter()
            .enumerate()
            .max_by_key(|(_, f)| f.admit_serial)
            .map(|(i, _)| i)
            .expect("youngest_idx on empty running set")
    }

    /// Free a running sequence's blocks and requeue it for recompute.
    fn preempt(&mut self, idx: usize) {
        let f = self.running.remove(idx);
        self.preemptions += 1;
        log::info!(
            "req {}: preempted under memory pressure (freeing {} blocks, {} tokens kept for replay)",
            f.req.id,
            B::cache(&f.seq).n_blocks(),
            f.produced.len()
        );
        let Inflight {
            req,
            seq,
            enqueued,
            first_token_at,
            decode_seconds,
            produced,
            preemptions,
            ..
        } = f;
        drop(seq); // returns every block the victim held to the arena
        self.queue.push_front(QueueEntry {
            req,
            enqueued,
            resume: produced,
            first_token_at,
            decode_seconds,
            preemptions: preemptions + 1,
        });
    }

    fn retire(&mut self, f: Inflight<B::Seq>, errored: bool) {
        let ttft = f
            .first_token_at
            .map(|t| t.duration_since(f.enqueued).as_secs_f64())
            .unwrap_or(0.0);
        let n = f.produced.len();
        let tpot = if n > 1 {
            f.decode_seconds / (n - 1).max(1) as f64
        } else {
            f.decode_seconds
        };
        self.ttft.add(ttft * 1e3);
        self.tpot.add(tpot * 1e3);
        let finish = if errored {
            FinishReason::Error
        } else if f.req.eos_token.is_some() && f.produced.last() == f.req.eos_token.as_ref() {
            FinishReason::Eos
        } else {
            FinishReason::MaxTokens
        };
        let cache = B::cache(&f.seq);
        let live_cache_tokens = cache.live_tokens();
        let mut cache_stats = cache.stats.clone();
        cache_stats.preemptions = f.preemptions as u64;
        cache_stats.peak_arena_blocks = self.arena.stats().peak_used as u64;
        self.finished.push(RequestOutput {
            id: f.req.id,
            tokens: f.produced,
            finish,
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_len: f.req.prompt.len(),
            live_cache_tokens,
            preemptions: f.preemptions,
            cache_stats,
        });
        // f.seq drops here, returning its blocks to the arena
    }
}

impl Scheduler<crate::runtime::SimBackend> {
    /// Scheduler over the always-built deterministic sim backend.
    pub fn new_sim(cfg: SchedConfig) -> Self {
        let backend = crate::runtime::SimBackend::new(cfg.page_size);
        Self::with_backend(backend, cfg)
    }
}

#[cfg(feature = "xla")]
impl<'e> Scheduler<crate::runtime::ModelRunner<'e>> {
    /// Scheduler over the PJRT runtime (historical constructor).
    pub fn new(engine: &'e crate::runtime::Engine, cfg: SchedConfig) -> Result<Self> {
        let runner = crate::runtime::ModelRunner::new(engine, &cfg.model, cfg.page_size)?;
        Ok(Self::with_backend(runner, cfg))
    }
}
