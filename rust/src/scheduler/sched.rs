//! The continuous-batching scheduler: batched decode rounds over a shared
//! physical block arena, with watermark admission and swap-to-host
//! preemption under memory pressure.
//!
//! Each round:
//!
//!  1. **deadline sweep** — queued or running requests whose step
//!     deadline expired are finished NOW with whatever they produced
//!     ([`FinishReason::Deadline`]);
//!  2. **admission** — fill free concurrency slots from the queue,
//!     HIGHEST priority first (front-most within a class, so preemption
//!     victims requeued at the front still resume before fresh work of
//!     their class), gated on the arena's LOW watermark
//!     (`BlockManager::below_low_watermark`, O(1)) against the blocks the
//!     admission claims *immediately*: the policy-aware resident prompt
//!     minus the prompt blocks the prefix index will serve by refcount
//!     for a fresh request (memoized per queue entry against the prefix
//!     index's epoch, so gated retries skip the O(prompt) recompute), the
//!     exact snapshot size for a swapped victim. Decode-time growth is no
//!     longer reserved up front — worst-case estimates over-reserve
//!     precisely when unstructured policies fragment pages (the paper's
//!     Limitation 1); the low/high hysteresis band absorbs the optimism
//!     instead;
//!  3. **watermark preemption** — while usage exceeds the HIGH watermark,
//!     victim-select the LOWEST-priority running sequence (youngest
//!     within the class) and evict it proactively, before allocation
//!     hard-fails;
//!  4. **reservation** — every running sequence that needs a fresh block
//!     for this round's token claims it up front; if the arena still runs
//!     dry, preemption repeats (same victim order) until the round fits;
//!  5. **batched decode** — one `DecodeBackend::decode_batch` call for the
//!     whole running set; finished sequences retire from the results.
//!
//! Every lifecycle transition is emitted as a [`SeqEvent`] —
//! `Prefilled`/`Token`/`Preempted`/`Resumed`/`Finished` — drained via
//! [`Scheduler::take_events`] (the session API's feed). The legacy
//! [`Scheduler::take_finished`] survives as a compat shim over the same
//! stream: the concatenated `Token` payloads are bit-identical to the
//! `Finished` output's tokens, pinned in `tests/api_session.rs`.
//!
//! A preemption victim is parked in a bounded host [`SwapPool`] when the
//! backend can snapshot it (swap-to-host): readmission from the queue
//! front *restores* the snapshot — no prompt recompute, no token replay.
//! When the backend cannot snapshot, the snapshot no longer fits the
//! pool, or the pool LRU-dropped it to make room, the victim falls back
//! to the recompute path: the prompt is re-prefilled and the produced
//! tokens are replayed through decode (greedy decode is deterministic, so
//! both paths yield bit-identical outputs).
//!
//! [`Scheduler::cancel`] tears a request down SYNCHRONOUSLY wherever it
//! lives: a running sequence's cache is dropped (arena blocks released,
//! shared prefix pages unpinned by refcount), a parked snapshot is
//! discarded, a queue entry is purged. No `Finished` event is emitted —
//! cancellation is not completion.
//!
//! The scheduler is generic over [`DecodeBackend`], so the identical
//! admission/preemption/reservation/retire logic runs on the always-built
//! deterministic sim backend (tier-1 tests) and on the PJRT runner
//! (`--features xla`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::autotune::{self, AutotuneStats, PressureSnapshot};
use super::backend::{ClaimMemo, DecodeBackend, Prefilled, PrefillStep, Restored};
use super::engine::PressureHook;
use super::request::{FinishReason, Priority, Request, RequestOutput};
use super::swap::SwapPool;
use crate::api::SeqEvent;
use crate::eviction::{make_policy, AUTO_POLICY};
use crate::kvcache::{BlockAlloc, BlockManager, CacheStats};
use crate::runtime::model_runner::argmax;
use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub model: String,
    pub page_size: usize,
    /// Max sequences decoded concurrently (vLLM "max_num_seqs").
    pub max_concurrency: usize,
    /// Capacity of the shared physical block arena — the real global KV
    /// memory every sequence allocates from (stands in for GPU memory).
    pub max_live_blocks: usize,
    /// Admission watermark as a fraction of the arena: new work is
    /// admitted only while usage stays at or below it. `1.0` = admit up
    /// to raw capacity.
    pub watermark_low: f64,
    /// Preemption watermark as a fraction of the arena: usage above it
    /// triggers proactive preemption. Must be `>= watermark_low`; the gap
    /// is the hysteresis band that absorbs decode-time growth.
    pub watermark_high: f64,
    /// Byte cap of the host-side swap pool preemption victims are parked
    /// in. `0` disables swap: every victim recomputes on readmission.
    pub swap_bytes: usize,
    /// Automatic prefix caching: prefills publish their full prompt
    /// blocks into the arena's content-hash index and map identical
    /// leading blocks by refcount instead of re-materializing them
    /// (`--prefix-cache on|off`). Greedy outputs are bit-identical either
    /// way — pinned in `tests/prefix_cache.rs` — only the physical
    /// footprint and prefill work change.
    pub prefix_cache: bool,
    /// Server-wide eviction policy a request inherits unless it carries
    /// its own override (`api::RequestBuilder::policy`).
    pub default_policy: String,
    /// Server-wide KV budget (tokens) a request inherits unless it
    /// carries its own override (`api::RequestBuilder::budget`).
    pub default_budget: usize,
    /// Per-request budget of TRANSIENT decode-error retries. A transient
    /// error within budget suspends the request through the normal
    /// preemption/readmission machinery (recompute-and-replay keeps the
    /// recovered output bit-identical); once exhausted the request
    /// retires as [`FinishReason::Error`].
    pub max_transient_retries: u32,
    /// Circuit breaker: a request whose decode fails this many CONSECUTIVE
    /// times (streak resets on any successful step, survives suspension)
    /// is quarantined as [`FinishReason::Error`] even with retry budget
    /// left — a poison request must not grind the batch forever.
    pub fault_streak_limit: u32,
    /// Worker threads the multi-worker engine shards the request stream
    /// across ([`super::engine::MultiEngine`]). Each worker runs its own
    /// round loop over its shard; the arena, swap pool and prefix index
    /// are shared. `1` = the classic single-threaded scheduler. Per-request
    /// outputs are bit-identical at any worker count (greedy decode is
    /// placement-independent) — pinned in `tests/multi_worker.rs`.
    pub workers: usize,
    /// Chunked prefill: a prompt longer than this many tokens is prefilled
    /// across multiple rounds (`prefill_chunk` prompt tokens per round)
    /// instead of head-of-line blocking one round on the whole prompt —
    /// decoders already running keep producing a token every round while
    /// the big prompt streams in. `0` disables chunking (every prefill is
    /// one-shot, the historical behavior). Outputs are bit-identical
    /// either way — chunking slices compute, never content — pinned in
    /// `tests/slo_workload.rs`. Backends that cannot chunk
    /// ([`DecodeBackend::prefill_begin`] returns `None`) fall back to
    /// one-shot regardless.
    pub prefill_chunk: usize,
}

/// Default worker count: saturate up to four cores, never oversubscribe a
/// smaller machine.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(1)
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            model: "sim-1b".into(),
            page_size: 16,
            max_concurrency: 8,
            max_live_blocks: 4096,
            watermark_low: 0.85,
            watermark_high: 0.95,
            swap_bytes: 64 << 20,
            prefix_cache: true,
            default_policy: "paged".into(),
            default_budget: 1024,
            max_transient_retries: 8,
            fault_streak_limit: 4,
            // library default stays single-threaded (embedding a scheduler
            // must not spawn threads behind the caller's back); the CLI
            // flags default to `default_workers()`
            workers: 1,
            // chunking off by default: every historical pin (bit-identity,
            // call counts, round accounting) sees the one-shot path
            prefill_chunk: 0,
        }
    }
}

/// What happened during one scheduling round (for traces/benches).
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub prefilled: usize,
    pub decoded_tokens: usize,
    pub finished: usize,
    /// Sequences preempted this round (watermark crossed or arena dry).
    pub preempted: usize,
    /// Sequences readmitted this round by restoring a swap-to-host
    /// snapshot (the `prefilled` count covers recompute readmissions).
    pub swap_restored: usize,
    /// Requests rejected outright (can never fit / bad policy / failed).
    pub rejected: usize,
    /// Requests finished this round because their step deadline expired
    /// (counted in `finished` too when they were running).
    pub expired: usize,
    /// Prompt blocks this round's prefills mapped from the prefix index
    /// (refcount + 1 on an existing page) instead of allocating.
    pub prefix_hit_blocks: usize,
    /// Copy-on-write page copies made while preparing this round (shared
    /// pages unshared ahead of in-place token kills) by sequences still
    /// running at decode time; copies made by a sequence preempted in the
    /// same round fold into the scheduler-level `cow_copies` aggregate
    /// instead.
    pub cow_copies: usize,
    /// Sequences suspended this round to retry a TRANSIENT decode error
    /// (not counted in `preempted` — no memory pressure was involved).
    pub retried: usize,
    /// Chunked-prefill advances this round: each is one `prefill_chunk`
    /// slice of some prompt fed through the backend while the decode
    /// batch ran anyway (a completed chunked prefill also counts in
    /// `prefilled` on its final chunk).
    pub chunk_prefills: usize,
}

/// Queued request plus everything needed to resume it after preemption —
/// by either path: `resume`/`swap_fed` keep the recompute replay valid
/// even while a snapshot is parked in the swap pool, so an LRU-dropped
/// snapshot silently degrades to recompute instead of losing work.
///
/// Generic over the backend's [`DecodeBackend::PrefillPlan`] so the
/// admission claim scan's artifact rides the entry to the prefill that
/// consumes it (fields stay private to this module; the multi-worker
/// engine moves entries between schedulers opaquely via
/// [`Scheduler::steal_tail`] / [`Scheduler::inject`]).
pub(crate) struct QueueEntry<P> {
    req: Request,
    enqueued: Instant,
    /// Tokens produced before preemption, replayed on readmission.
    resume: Vec<u32>,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    preemptions: u32,
    /// Swap-restore readmissions so far.
    swaps: u32,
    /// How many of `resume` were already fed through decode when the
    /// sequence was preempted — the restore point for a swap readmission
    /// (recompute readmissions replay from 0).
    swap_fed: usize,
    /// Pending next token at preemption time, consumed by a swap restore
    /// once `swap_fed == resume.len()` (recompute recomputes it).
    next_token: u32,
    /// Absolute step (scheduler round) at which the deadline expires.
    deadline_at: Option<u64>,
    /// Memoized admission claim, valid while the prefix index epoch it
    /// was recorded against is current.
    claim: Option<ClaimMemo>,
    /// The claim scan's backend-opaque artifact (e.g. the sim backend's
    /// kept-entry list): a pure function of the immutable request, so it
    /// stays valid for the entry's whole queued life — the admitting
    /// prefill consumes it instead of re-running the policy scan.
    plan: Option<P>,
    /// Transient decode-error retries consumed so far.
    retries: u32,
    /// Consecutive decode failures (survives suspension; resets on any
    /// successful step) — the circuit breaker's counter.
    fault_streak: u32,
}

impl<P> QueueEntry<P> {
    fn fresh(req: Request, deadline_at: Option<u64>) -> QueueEntry<P> {
        QueueEntry {
            req,
            enqueued: Instant::now(),
            resume: Vec::new(),
            first_token_at: None,
            decode_seconds: 0.0,
            preemptions: 0,
            swaps: 0,
            swap_fed: 0,
            next_token: 0,
            deadline_at,
            claim: None,
            plan: None,
            retries: 0,
            fault_streak: 0,
        }
    }
}

/// Book-keeping for an in-flight request.
struct Inflight<S> {
    req: Request,
    seq: S,
    next_token: u32,
    enqueued: Instant,
    first_token_at: Option<Instant>,
    decode_seconds: f64,
    /// All tokens produced (including pre-preemption history).
    produced: Vec<u32>,
    /// How many of `produced` have been fed back through decode; while
    /// `fed < produced.len()` the sequence is replaying after preemption.
    fed: usize,
    /// Monotonic admission number — preemption victims are the youngest
    /// of the lowest-priority class.
    admit_serial: u64,
    preemptions: u32,
    /// Swap-restore readmissions for this request.
    swaps: u32,
    /// `stats.cow_copies` watermark already folded into the scheduler's
    /// round/aggregate counters (delta accounting across rounds).
    cow_seen: u64,
    /// Absolute step at which the deadline expires.
    deadline_at: Option<u64>,
    /// Transient decode-error retries consumed so far.
    retries: u32,
    /// Consecutive decode failures (circuit-breaker counter).
    fault_streak: u32,
}

enum AdmitOutcome<P> {
    /// `restored` distinguishes a swap-pool restore from a prefill (fresh
    /// or recompute) for the round report; `hit_blocks` is the prefix-
    /// index hit count of that prefill (0 for restores).
    Admitted { restored: bool, hit_blocks: u64 },
    /// Admission started a CHUNKED prefill: the entry now lives in
    /// `Scheduler::prefilling` and advances one chunk per round until its
    /// final chunk claims the cache and it joins `running`. It occupies a
    /// concurrency slot from this moment (it is in-flight work).
    Chunking,
    /// Arena too full right now; entry comes back for a later round.
    OutOfMemory(QueueEntry<P>),
    /// Request failed hard (error output already emitted).
    Failed,
}

pub struct Scheduler<B: DecodeBackend> {
    pub cfg: SchedConfig,
    backend: B,
    arena: BlockManager,
    /// Admission buckets, highest priority first (`Self::bucket`): pop =
    /// front of the first non-empty bucket, O(1) — highest class first,
    /// front-most within a class, preemption victims requeued at their
    /// class front. No cross-bucket scan per admission.
    queues: [VecDeque<QueueEntry<B::PrefillPlan>>; 3],
    running: Vec<Inflight<B::Seq>>,
    /// In-progress CHUNKED prefills: admitted entries whose prompt is
    /// still streaming through the backend one `prefill_chunk` per round.
    /// A job holds NO arena blocks (the packed cache is claimed at the
    /// final chunk), so dropping one — cancel, deadline, shutdown — is
    /// free. Each occupies a concurrency slot like a running sequence.
    prefilling: Vec<(QueueEntry<B::PrefillPlan>, B::PrefillJob)>,
    /// Lifecycle events in emission order, keyed by request id — the
    /// session API's feed ([`Scheduler::take_events`]).
    events: VecDeque<(u64, SeqEvent)>,
    /// Emit the STREAMING events (`Prefilled`/`Token`/`Preempted`/
    /// `Resumed`)? Terminal `Finished` events are always emitted. Off by
    /// default so legacy `take_finished` drains buffer O(requests), not
    /// O(total tokens); the session API turns it on.
    stream_events: bool,
    /// Host-side pool of swapped-out victims (byte-capped LRU). Shared by
    /// every worker of a multi-worker engine: a victim parked by one
    /// worker restores on whichever worker readmits (or steals) it.
    swap: Arc<SwapPool<B::Snapshot>>,
    // aggregate serving metrics
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub decode_step_s: Summary,
    pub total_generated: u64,
    pub total_prompt_tokens: u64,
    /// Total sequences preempted (memory pressure) since start — both
    /// readmission paths.
    pub preemptions: u64,
    /// Preemption victims successfully parked in the swap pool.
    pub swap_outs: u64,
    /// Readmissions served by restoring a snapshot (no recompute).
    pub swap_restores: u64,
    /// Total prompt blocks served from the prefix index across all
    /// prefills (including recompute readmissions — those hits are real
    /// arena events too).
    pub prefix_hit_blocks: u64,
    /// Total copy-on-write page copies made during round preparation.
    pub cow_copies: u64,
    /// Total chunked-prefill advances since start (see
    /// [`StepReport::chunk_prefills`]).
    pub chunk_prefills: u64,
    /// Total TRANSIENT decode errors recovered by suspend-and-retry.
    pub fault_retries: u64,
    /// Requests retired as [`FinishReason::Error`] by the retry budget or
    /// the consecutive-failure circuit breaker (poison quarantine) —
    /// terminal backend errors are not counted here.
    pub quarantined: u64,
    /// Aggregate cache counters of CANCELLED requests (each cancelled
    /// sequence's final stats merged with `cancelled = 1`; queued cancels
    /// contribute the count alone). `cancelled_stats.cancelled` is the
    /// total cancel count.
    pub cancelled_stats: CacheStats,
    /// `--policy auto` resolutions by chosen policy (empty unless the
    /// autotuner ran). Per-worker; the engine merges across workers.
    pub autotune: AutotuneStats,
    started: Option<Instant>,
    /// Admission serial source — shared across a multi-worker engine's
    /// schedulers so `(priority, Reverse(admit_serial))` victim keys are
    /// globally comparable (the cross-worker preemption rule).
    admit_counter: Arc<AtomicU64>,
    /// Scheduling rounds started so far (the deadline clock). Per-worker:
    /// deadline-carrying entries are never stolen across workers.
    steps: u64,
    /// Installed by the multi-worker engine: lets a starved worker see
    /// global in-flight work and post reclaim pressure instead of
    /// rejecting or erroring a request that another worker could make
    /// room for.
    hook: Option<PressureHook>,
}

impl<B: DecodeBackend> Scheduler<B> {
    /// Build a scheduler around an existing backend. The shared arena is
    /// sized by `cfg.max_live_blocks` with the configured admission /
    /// preemption watermark band.
    pub fn with_backend(backend: B, cfg: SchedConfig) -> Self {
        let arena = BlockManager::new(cfg.max_live_blocks);
        arena.set_watermarks(cfg.watermark_low, cfg.watermark_high);
        let swap = Arc::new(SwapPool::new(cfg.swap_bytes));
        Self::with_shared(backend, cfg, arena, swap, Arc::new(AtomicU64::new(0)))
    }

    /// Build a scheduler over resources owned elsewhere: the multi-worker
    /// engine hands every worker the SAME arena (one physical block pool,
    /// one prefix index — a prefix published by worker A is a free hit
    /// for worker B), the SAME swap pool and the SAME admission-serial
    /// source. Watermarks are the caller's job (`with_backend` sets them
    /// on its fresh arena; the engine sets them once on the shared one).
    pub fn with_shared(
        mut backend: B,
        cfg: SchedConfig,
        arena: BlockManager,
        swap: Arc<SwapPool<B::Snapshot>>,
        admit_counter: Arc<AtomicU64>,
    ) -> Self {
        backend.set_prefix_cache(cfg.prefix_cache);
        // Bind this worker's slot cache: every SeqCache created through
        // this handle allocs/frees against a small leased stock, so the
        // decode steady state never touches the global arena lock. Leased
        // slots still count as free globally; a dry peer drains them back
        // (see block_manager's lease/drain protocol).
        let arena = arena.with_worker_cache();
        Scheduler {
            cfg,
            backend,
            arena,
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            running: Vec::new(),
            prefilling: Vec::new(),
            events: VecDeque::new(),
            stream_events: false,
            swap,
            ttft: Histogram::new(),
            tpot: Histogram::new(),
            decode_step_s: Summary::new(),
            total_generated: 0,
            total_prompt_tokens: 0,
            preemptions: 0,
            swap_outs: 0,
            swap_restores: 0,
            prefix_hit_blocks: 0,
            cow_copies: 0,
            chunk_prefills: 0,
            fault_retries: 0,
            quarantined: 0,
            cancelled_stats: CacheStats::default(),
            autotune: AutotuneStats::default(),
            started: None,
            admit_counter,
            steps: 0,
            hook: None,
        }
    }

    /// The shared physical block arena (O(1) global accounting).
    pub fn arena(&self) -> &BlockManager {
        &self.arena
    }

    /// Return this worker's leased slot stock to the global free list.
    /// The multi-worker engine calls this when the worker goes idle: an
    /// idle worker's lease is pure inventory peers would otherwise have
    /// to reclaim through a dry-arena drain. Returns the slots flushed.
    pub fn flush_slot_cache(&self) -> usize {
        self.arena.flush_local_cache()
    }

    /// The decode backend (read-only; for stats/introspection).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Consume the scheduler and return its backend — the multi-worker
    /// engine hands each worker's backend back at shutdown so callers can
    /// read interior counters (sim call tallies, fault counts).
    pub fn into_backend(self) -> B {
        self.backend
    }

    /// The host-side swap pool (byte accounting, LRU drop count).
    pub fn swap_pool(&self) -> &SwapPool<B::Snapshot> {
        &self.swap
    }

    pub fn submit(&mut self, mut req: Request) {
        if req.policy == AUTO_POLICY {
            // Resolve the sentinel NOW, before any budget check or queue
            // state sees the request: policy + budget become ordinary
            // per-request overrides (the PR 5 machinery), and everything
            // downstream — admission pricing, prefill, snapshots, the
            // surfaced `RequestOutput::policy` — sees only the concrete
            // choice. The decision is a pure function of (request,
            // pressure snapshot, prefix-hit depth); see
            // `scheduler::autotune` for why that keeps multi-worker
            // digests bit-identical.
            let snap = PressureSnapshot::read(&self.arena);
            let hits = self.backend.shared_prefix_depth(&self.arena, &req.prompt);
            let choice =
                autotune::choose(req.prompt.len(), hits, req.budget, self.cfg.page_size, &snap);
            log::debug!(
                "req {}: auto -> {} (budget {} -> {}, band {:?}, prefix hits {hits})",
                req.id,
                choice.policy,
                req.budget,
                choice.budget,
                snap.band()
            );
            req.policy = choice.policy.to_string();
            req.budget = choice.budget;
            self.autotune.record(choice.policy);
        }
        if req.budget == 0 {
            // A zero-token cache cannot hold even the incoming token; the
            // old code silently floored this to 2 blocks. Reject it.
            log::warn!("req {}: zero cache budget — rejected", req.id);
            let out = Self::error_output(&req);
            self.emit(req.id, SeqEvent::Finished(out));
            return;
        }
        if req.budget < self.cfg.page_size {
            // Sub-page budgets are clamped up: one page is the smallest
            // unit the paged layout can serve.
            log::debug!(
                "req {}: budget {} below page size {} — clamped",
                req.id,
                req.budget,
                self.cfg.page_size
            );
            req.budget = self.cfg.page_size;
        }
        // resolve the relative deadline against the round clock NOW: the
        // request gets `deadline_steps` full rounds after submission
        let deadline_at = req.deadline_steps.map(|d| self.steps + d);
        let bucket = Self::bucket(req.priority);
        self.queues[bucket].push_back(QueueEntry::fresh(req, deadline_at));
    }

    /// Admission-bucket index of a priority class (highest first).
    fn bucket(p: Priority) -> usize {
        match p {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>() + self.prefilling.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Chunked prefills currently in progress (each advances one
    /// `prefill_chunk` of prompt per round until its final chunk claims
    /// the cache and it starts decoding).
    pub fn prefilling(&self) -> usize {
        self.prefilling.len()
    }

    /// Allocated blocks across ALL sequences — O(1) from the arena, not a
    /// scan over running sequences.
    pub fn live_blocks(&self) -> usize {
        self.arena.used()
    }

    /// Scheduling rounds started so far (the deadline clock).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Requests cancelled so far.
    pub fn cancelled(&self) -> u64 {
        self.cancelled_stats.cancelled
    }

    pub fn is_idle(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
            && self.running.is_empty()
            && self.prefilling.is_empty()
    }

    /// Ids of every live (queued or running) request. Drain/shutdown
    /// paths use this to cancel whatever outlasted the grace deadline.
    pub fn live_ids(&self) -> Vec<u64> {
        self.queues
            .iter()
            .flat_map(|q| q.iter().map(|e| e.req.id))
            .chain(self.prefilling.iter().map(|(e, _)| e.req.id))
            .chain(self.running.iter().map(|f| f.req.id))
            .collect()
    }

    fn emit(&mut self, id: u64, ev: SeqEvent) {
        self.events.push_back((id, ev));
    }

    /// Emit a non-terminal streaming event (dropped unless streaming is
    /// enabled both scheduler-wide — [`Scheduler::set_event_streaming`] —
    /// and on the request itself — `Request::stream_events`).
    fn emit_stream(&mut self, req: &Request, ev: SeqEvent) {
        if self.stream_events && req.stream_events {
            self.events.push_back((req.id, ev));
        }
    }

    /// Enable per-token/lifecycle streaming events. The session API turns
    /// this on; legacy `take_finished`-only consumers leave it off so the
    /// event buffer stays O(finished requests) between drains.
    pub fn set_event_streaming(&mut self, enabled: bool) {
        self.stream_events = enabled;
    }

    /// Drain every lifecycle event emitted since the last drain, in
    /// emission order. The session API's feed. Without
    /// [`Scheduler::set_event_streaming`] only terminal `Finished` events
    /// appear here.
    pub fn take_events(&mut self) -> Vec<(u64, SeqEvent)> {
        self.events.drain(..).collect()
    }

    /// Compat shim over the event stream: drains ALL pending events and
    /// returns only the terminal outputs, discarding the streaming
    /// events. Callers that want the full stream use
    /// [`Scheduler::take_events`] (or the session API) instead — the two
    /// never compose on one scheduler, they drain the same queue.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        self.events
            .drain(..)
            .filter_map(|(_, ev)| match ev {
                SeqEvent::Finished(out) => Some(out),
                _ => None,
            })
            .collect()
    }

    /// Cancel a request wherever it lives. Synchronous: on `true`, the
    /// blocks of a mid-decode sequence are already back in the arena
    /// (shared prefix pages unpinned by refcount — a page a live sharer
    /// holds survives, the hard-error arena guarantees it), any parked
    /// swap snapshot is discarded, and the queue entry is purged. No
    /// `Finished` event is emitted — cancellation is not completion.
    /// `false` when the id is unknown or already finished: a clean no-op.
    pub fn cancel(&mut self, id: u64) -> bool {
        for q in self.queues.iter_mut() {
            let Some(pos) = q.iter().position(|e| e.req.id == id) else {
                continue;
            };
            let entry = q.remove(pos).expect("position just found");
            self.swap.discard(id);
            self.cancelled_stats.cancelled += 1;
            self.cancelled_stats.preemptions += entry.preemptions as u64;
            self.cancelled_stats.swaps += entry.swaps as u64;
            self.cancelled_stats.retries += entry.retries as u64;
            log::info!("req {id}: cancelled while queued");
            return true;
        }
        if let Some(pos) = self.prefilling.iter().position(|(e, _)| e.req.id == id) {
            let (entry, job) = self.prefilling.remove(pos);
            drop(job); // an in-progress chunked prefill holds no blocks
            self.swap.discard(id);
            self.cancelled_stats.cancelled += 1;
            self.cancelled_stats.preemptions += entry.preemptions as u64;
            self.cancelled_stats.swaps += entry.swaps as u64;
            self.cancelled_stats.retries += entry.retries as u64;
            log::info!("req {id}: cancelled mid-chunked-prefill");
            return true;
        }
        if let Some(pos) = self.running.iter().position(|f| f.req.id == id) {
            let f = self.running.remove(pos);
            let n_blocks = B::cache(&f.seq).n_blocks();
            // fold not-yet-counted copy-on-write work (same rule as
            // preemption: the victim misses the post-reservation pass)
            self.cow_copies += B::cache(&f.seq).stats.cow_copies - f.cow_seen;
            let mut st = B::cache(&f.seq).stats.clone();
            st.cancelled = 1;
            st.preemptions = f.preemptions as u64;
            st.swaps = f.swaps as u64;
            st.retries = f.retries as u64;
            self.cancelled_stats.merge(&st);
            self.swap.discard(id); // nothing should be parked; be thorough
            log::info!("req {id}: cancelled mid-decode (releasing {n_blocks} blocks)");
            drop(f); // seq drop returns every block by refcount
            return true;
        }
        false
    }

    fn error_output(req: &Request) -> RequestOutput {
        RequestOutput {
            id: req.id,
            tokens: Vec::new(),
            policy: req.policy.clone(),
            finish: FinishReason::Error,
            ttft_s: 0.0,
            tpot_s: 0.0,
            prompt_len: req.prompt.len(),
            live_cache_tokens: 0,
            preemptions: 0,
            swaps: 0,
            retries: 0,
            cache_stats: Default::default(),
        }
    }

    /// Finish a QUEUED entry whose deadline expired: it holds no blocks
    /// (a preempted one only a possible snapshot), so teardown is a
    /// discard plus the terminal event carrying whatever it produced.
    fn expire_queued(&mut self, entry: QueueEntry<B::PrefillPlan>) {
        self.swap.discard(entry.req.id);
        let ttft = entry
            .first_token_at
            .map(|t| t.duration_since(entry.enqueued).as_secs_f64())
            .unwrap_or(0.0);
        // a preempted victim may have produced tokens before parking:
        // derive tpot from its accumulated decode time, like retire()
        let n = entry.resume.len();
        let tpot = if n > 1 {
            entry.decode_seconds / (n - 1) as f64
        } else {
            entry.decode_seconds
        };
        let out = RequestOutput {
            id: entry.req.id,
            tokens: entry.resume,
            policy: entry.req.policy.clone(),
            finish: FinishReason::Deadline,
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_len: entry.req.prompt.len(),
            live_cache_tokens: 0,
            preemptions: entry.preemptions,
            swaps: entry.swaps,
            retries: entry.retries,
            cache_stats: CacheStats {
                preemptions: entry.preemptions as u64,
                swaps: entry.swaps as u64,
                retries: entry.retries as u64,
                ..Default::default()
            },
        };
        log::info!(
            "req {}: deadline expired while queued ({} tokens kept)",
            entry.req.id,
            out.tokens.len()
        );
        self.emit(entry.req.id, SeqEvent::Finished(out));
    }

    /// One scheduling round: expire deadlines, admit, reserve (preempting
    /// under pressure), one batched decode for the whole running set,
    /// retire finished.
    pub fn step(&mut self) -> Result<StepReport> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.steps += 1;
        let now_step = self.steps;
        let mut report = StepReport::default();

        // --- deadline sweep: a request past its step deadline finishes
        // NOW with whatever it has — queued (incl. swapped-out victims:
        // snapshot discarded) and running (retired, blocks freed) ---
        for b in 0..self.queues.len() {
            let mut qi = 0;
            while qi < self.queues[b].len() {
                if self.queues[b][qi].deadline_at.is_some_and(|d| now_step > d) {
                    let entry = self.queues[b].remove(qi).expect("index in range");
                    self.expire_queued(entry);
                    report.expired += 1;
                } else {
                    qi += 1;
                }
            }
        }
        let mut ri = 0;
        while ri < self.running.len() {
            if self.running[ri].deadline_at.is_some_and(|d| now_step > d) {
                let f = self.running.remove(ri);
                log::info!("req {}: deadline expired mid-decode", f.req.id);
                self.retire(f, Some(FinishReason::Deadline));
                report.expired += 1;
                report.finished += 1;
            } else {
                ri += 1;
            }
        }

        // --- chunked-prefill advance: every in-progress chunked prefill
        // streams one more `prefill_chunk` of prompt through the backend.
        // A job that finishes its FINAL chunk claims its packed cache and
        // joins the running set in time for this round's decode; one whose
        // claim fails goes back to its queue front (a job holds no blocks,
        // so abandoning it frees nothing and costs nothing). Expired
        // deadlines are handled here too — the job drops for free. ---
        if !self.prefilling.is_empty() {
            let jobs = std::mem::take(&mut self.prefilling);
            for (entry, job) in jobs {
                if entry.deadline_at.is_some_and(|d| now_step > d) {
                    drop(job);
                    self.expire_queued(entry);
                    report.expired += 1;
                    continue;
                }
                match self.backend.prefill_advance(job, self.cfg.prefill_chunk) {
                    Ok(PrefillStep::More(job)) => {
                        report.chunk_prefills += 1;
                        self.chunk_prefills += 1;
                        self.prefilling.push((entry, job));
                    }
                    Ok(PrefillStep::Done { seq, logits }) => {
                        report.chunk_prefills += 1;
                        self.chunk_prefills += 1;
                        report.prefilled += 1;
                        let hit_blocks = self.admit_ready(entry, seq, logits);
                        report.prefix_hit_blocks += hit_blocks as usize;
                        self.prefix_hit_blocks += hit_blocks;
                    }
                    Ok(PrefillStep::OutOfMemory) => {
                        // the completion claim did not fit — requeue at the
                        // bucket front and retry once capacity frees (the
                        // folded compute is redone; correctness needs
                        // nothing from the abandoned job)
                        log::info!(
                            "req {}: chunked prefill claim ran the arena dry — requeued",
                            entry.req.id
                        );
                        let bucket = Self::bucket(entry.req.priority);
                        self.queues[bucket].push_front(entry);
                    }
                    Err(e) => {
                        log::warn!("req {}: chunked prefill failed: {e:#}", entry.req.id);
                        let out = Self::error_output(&entry.req);
                        self.emit(entry.req.id, SeqEvent::Finished(out));
                        report.rejected += 1;
                    }
                }
            }
        }

        // --- admission: fill every free concurrency slot, HIGHEST
        // priority first (front-most within a class), gated on the
        // arena's low watermark against what the admission claims NOW:
        // the policy-aware resident prompt MINUS the blocks the prefix
        // index will serve by refcount (`DecodeBackend::prefill_claim`,
        // memoized on the queue entry against the prefix-index epoch so
        // gated retries skip the O(prompt) recompute), or a swapped
        // victim's exact snapshot size. Worst-case decode growth is never
        // reserved: the low/high hysteresis band absorbs it and
        // preemption above the high mark reclaims it (the old worst-case
        // gate over-reserved exactly when unstructured policies fragment
        // pages — the paper's Limitation 1) ---
        while self.running.len() + self.prefilling.len() < self.cfg.max_concurrency {
            let Some(b) = (0..self.queues.len()).find(|&b| !self.queues[b].is_empty())
            else {
                break;
            };
            let mut entry = self.queues[b].pop_front().expect("non-empty bucket");
            let incoming = match self.swap.arena_blocks_of(entry.req.id) {
                Some(blocks) => blocks,
                None => match entry.claim.and_then(|m| m.get(&self.arena)) {
                    Some(blocks) => blocks,
                    None => {
                        let (blocks, plan) = self.backend.prefill_claim_planned(
                            &self.arena,
                            &entry.req,
                            self.cfg.page_size,
                        );
                        entry.claim = Some(ClaimMemo::record(&self.arena, blocks));
                        // keep the scan's artifact for the prefill (the
                        // plan is request-pure, so it outlives any prefix
                        // epoch the block memo above is keyed on)
                        if plan.is_some() {
                            entry.plan = plan;
                        }
                        blocks
                    }
                },
            };
            // With nothing running ANYWHERE the gate is bypassed: no
            // sequence can ever free blocks, so either the admission fits
            // the raw capacity now or the request can never run (rejected
            // below when its prefill runs the arena dry). Under a multi-
            // worker engine, OTHER workers' sequences also free shared
            // arena blocks — a locally-idle worker must still gate, and
            // posts reclaim pressure so the global victim rule picks who
            // pays.
            if !self.arena.below_low_watermark(incoming)
                && (!self.running.is_empty()
                    || !self.prefilling.is_empty()
                    || self.others_running() > 0)
            {
                if self.running.is_empty() {
                    self.post_pressure();
                }
                // not enough global KV headroom yet — head-of-line wait
                // (back to its bucket front, order preserved)
                self.queues[b].push_front(entry);
                break;
            }
            match self.admit(entry) {
                AdmitOutcome::Admitted { restored, hit_blocks } => {
                    if restored {
                        report.swap_restored += 1;
                    } else {
                        report.prefilled += 1;
                    }
                    report.prefix_hit_blocks += hit_blocks as usize;
                    self.prefix_hit_blocks += hit_blocks;
                }
                AdmitOutcome::Chunking => {
                    report.chunk_prefills += 1;
                    self.chunk_prefills += 1;
                }
                AdmitOutcome::OutOfMemory(entry) => {
                    if self.running.is_empty() && self.prefilling.is_empty() {
                        if self.others_running() > 0 {
                            // another worker's sequences hold the shared
                            // arena: ask the engine to reclaim globally
                            // and retry instead of rejecting
                            self.post_pressure();
                            self.queues[b].push_front(entry);
                            break;
                        }
                        // nothing in flight can ever free blocks for it:
                        // the packed prompt simply does not fit the arena
                        log::warn!(
                            "req {}: prefill exceeds the {}-block arena — rejected",
                            entry.req.id,
                            self.arena.capacity()
                        );
                        self.swap.discard(entry.req.id);
                        let out = Self::error_output(&entry.req);
                        self.emit(entry.req.id, SeqEvent::Finished(out));
                        report.rejected += 1;
                        continue;
                    }
                    self.queues[b].push_front(entry);
                    break;
                }
                AdmitOutcome::Failed => report.rejected += 1,
            }
        }

        // --- high-watermark preemption: reclaim the admission optimism
        // proactively, before allocation hard-fails (the hysteresis
        // partner of the low-mark admission gate) ---
        while self.arena.above_high_watermark() && self.running.len() > 1 {
            let victim = self.victim_idx();
            self.preempt(victim);
            report.preempted += 1;
        }
        // Still above the mark with at most one local runner: under a
        // multi-worker engine the overshoot belongs to the SHARED arena —
        // post pressure so the worker owning the global victim reclaims.
        if self.arena.above_high_watermark()
            && self.running.len() <= 1
            && self.others_running() > 0
        {
            self.post_pressure();
        }

        // --- reservation + preemption: every sequence that needs a fresh
        // block for this round claims it now — and every sequence whose
        // policy will hole-punch tokens in place gets its shared prefix
        // pages copied-on-write (`prepare_round`) — so the batched decode
        // below can neither fail on memory nor write a shared page ---
        let mut i = 0;
        while i < self.running.len() {
            let outcome = match self.backend.prepare_round(&mut self.running[i].seq) {
                BlockAlloc::Ready => {
                    B::cache_mut(&mut self.running[i].seq).try_ensure_block()
                }
                blocked => blocked,
            };
            match outcome {
                BlockAlloc::Ready => i += 1,
                BlockAlloc::BucketFull => {
                    if let Err(e) = self.backend.grow_bucket(&mut self.running[i].seq) {
                        log::warn!(
                            "req {}: bucket growth failed: {e:#}",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, Some(FinishReason::Error));
                        report.finished += 1;
                    }
                    // retry the same index (grown) or the shifted one
                }
                BlockAlloc::ArenaDry => {
                    if self.running.len() == 1 {
                        if self.others_running() > 0 {
                            // other workers' sequences hold the shared
                            // arena: park the lone local runner (lossless
                            // — restore-or-replay) and post pressure so
                            // the global victim rule frees real memory,
                            // instead of erroring a recoverable request
                            log::info!(
                                "req {}: arena dry with no local victim — \
                                 parked pending cross-worker reclaim",
                                self.running[i].req.id
                            );
                            self.preempt(i);
                            report.preempted += 1;
                            self.post_pressure();
                            continue;
                        }
                        // no victim can free memory for this sequence
                        log::warn!(
                            "req {}: arena exhausted with no preemption victim",
                            self.running[i].req.id
                        );
                        let f = self.running.remove(i);
                        self.retire(f, Some(FinishReason::Error));
                        report.finished += 1;
                    } else {
                        let victim = self.victim_idx();
                        self.preempt(victim);
                        report.preempted += 1;
                        i = 0; // indices shifted and capacity freed: rescan
                    }
                }
            }
        }

        // fold this round's copy-on-write work into the report/aggregates
        // (delta against each sequence's last-seen counter)
        for f in self.running.iter_mut() {
            let cow = B::cache(&f.seq).stats.cow_copies;
            report.cow_copies += (cow - f.cow_seen) as usize;
            self.cow_copies += cow - f.cow_seen;
            f.cow_seen = cow;
        }

        // --- batched decode: ONE backend call for the whole running set ---
        if self.running.is_empty() {
            return Ok(report);
        }
        let t0 = Instant::now();
        let toks: Vec<u32> = self
            .running
            .iter()
            .map(|f| if f.fed < f.produced.len() { f.produced[f.fed] } else { f.next_token })
            .collect();
        let mut batch: Vec<(&mut B::Seq, u32)> = self
            .running
            .iter_mut()
            .zip(toks.iter().copied())
            .map(|(f, t)| (&mut f.seq, t))
            .collect();
        let results = self.backend.decode_batch(&mut batch);
        drop(batch);
        let round_s = t0.elapsed().as_secs_f64();
        self.decode_step_s.add(round_s);
        let per_seq_s = round_s / self.running.len() as f64;
        debug_assert_eq!(results.len(), self.running.len(), "backend dropped entries");

        // What the retirement pass does with one decode result:
        //   Finish — natural completion (stop token / length);
        //   Fail   — retire as FinishReason::Error (`quarantined` marks a
        //            transient failure the retry budget / circuit breaker
        //            gave up on, as opposed to a terminal backend error);
        //   Retry  — transient error within budget: suspend through the
        //            preemption machinery and readmit (replay is lossless).
        enum RoundAction {
            Finish,
            Fail { quarantined: bool },
            Retry,
        }
        let mut actions: Vec<(usize, RoundAction)> = Vec::new();
        for (j, res) in results.into_iter().enumerate() {
            let f = &mut self.running[j];
            let tok = toks[j];
            report.decoded_tokens += 1;
            f.decode_seconds += per_seq_s;
            match res {
                Err(e) => {
                    f.fault_streak += 1;
                    let budget_left = f.retries < self.cfg.max_transient_retries;
                    let breaker_open = f.fault_streak >= self.cfg.fault_streak_limit;
                    if e.is_transient() && budget_left && !breaker_open {
                        log::warn!(
                            "req {}: transient decode error (retry {} of {}): {e:#}",
                            f.req.id,
                            f.retries + 1,
                            self.cfg.max_transient_retries,
                        );
                        actions.push((j, RoundAction::Retry));
                        continue;
                    }
                    let quarantined = e.is_transient();
                    if quarantined {
                        log::warn!(
                            "req {}: quarantined after {} retries (streak {}): {e:#}",
                            f.req.id,
                            f.retries,
                            f.fault_streak,
                        );
                    } else {
                        log::warn!("req {}: decode error: {e:#}", f.req.id);
                    }
                    if f.fed >= f.produced.len() {
                        f.produced.push(tok); // retire with what we have
                        if self.stream_events && f.req.stream_events {
                            self.events.push_back((
                                f.req.id,
                                SeqEvent::Token { tok, step: f.produced.len() - 1 },
                            ));
                        }
                    }
                    actions.push((j, RoundAction::Fail { quarantined }));
                }
                Ok(logits) => {
                    f.fault_streak = 0;
                    let replaying = f.fed < f.produced.len();
                    if replaying {
                        // replayed tokens were streamed before the
                        // preemption: never re-emitted
                        f.fed += 1;
                    } else {
                        f.produced.push(tok);
                        f.fed = f.produced.len();
                        self.total_generated += 1;
                        if self.stream_events && f.req.stream_events {
                            self.events.push_back((
                                f.req.id,
                                SeqEvent::Token { tok, step: f.produced.len() - 1 },
                            ));
                        }
                    }
                    f.next_token = argmax(&logits);
                    if !replaying {
                        let stop_hit = f.req.is_stop(tok);
                        if stop_hit || f.produced.len() >= f.req.max_new_tokens {
                            actions.push((j, RoundAction::Finish));
                        }
                    }
                }
            }
        }
        // Process in REVERSE index order: removals keep later indices
        // valid, and the reversed per-bucket push_fronts of a multi-entry
        // retry (whole-batch failure) land back in original queue order.
        for &(j, ref action) in actions.iter().rev() {
            match action {
                RoundAction::Finish => {
                    let f = self.running.remove(j);
                    self.retire(f, None);
                    report.finished += 1;
                }
                RoundAction::Fail { quarantined } => {
                    if *quarantined {
                        self.quarantined += 1;
                    }
                    let f = self.running.remove(j);
                    self.retire(f, Some(FinishReason::Error));
                    report.finished += 1;
                }
                RoundAction::Retry => {
                    self.suspend(j, true);
                    report.retried += 1;
                }
            }
        }
        Ok(report)
    }

    /// Run rounds until everything submitted so far is finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    /// Tokens (prompt+generated) per second since the first step — the
    /// paper's throughput metric (§5.1).
    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => {
                (self.total_prompt_tokens + self.total_generated) as f64
                    / t0.elapsed().as_secs_f64()
            }
            None => 0.0,
        }
    }

    fn admit(
        &mut self,
        entry: QueueEntry<B::PrefillPlan>,
    ) -> AdmitOutcome<B::PrefillPlan> {
        // A swapped-out victim readmits by restoring its snapshot: the
        // cache, policy state and model continuation come back exactly as
        // suspended — no prompt recompute, no token replay.
        if let Some(snap) = self.swap.take(entry.req.id) {
            match self.backend.restore(&self.arena, &snap) {
                Ok(Restored::Ready(seq)) => {
                    self.swap_restores += 1;
                    let serial = self.admit_counter.fetch_add(1, Ordering::Relaxed) + 1;
                    let fed = entry.swap_fed.min(entry.resume.len());
                    log::info!(
                        "req {}: restored from swap ({} tokens kept, {} to replay)",
                        entry.req.id,
                        entry.resume.len(),
                        entry.resume.len() - fed
                    );
                    self.emit_stream(&entry.req, SeqEvent::Resumed);
                    // the snapshot carries the cache's historical CoW
                    // count: seed the delta watermark so it is not
                    // recounted this round
                    let cow_seen = B::cache(&seq).stats.cow_copies;
                    self.running.push(Inflight {
                        next_token: entry.next_token,
                        first_token_at: entry.first_token_at,
                        enqueued: entry.enqueued,
                        decode_seconds: entry.decode_seconds,
                        produced: entry.resume,
                        fed,
                        admit_serial: serial,
                        preemptions: entry.preemptions,
                        swaps: entry.swaps + 1,
                        cow_seen,
                        deadline_at: entry.deadline_at,
                        retries: entry.retries,
                        fault_streak: entry.fault_streak,
                        req: entry.req,
                        seq,
                    });
                    return AdmitOutcome::Admitted { restored: true, hit_blocks: 0 };
                }
                Ok(Restored::OutOfMemory) => {
                    // keep the snapshot parked for a later retry
                    self.swap.insert(entry.req.id, snap);
                    return AdmitOutcome::OutOfMemory(entry);
                }
                Err(e) => {
                    log::warn!(
                        "req {}: swap restore failed — falling back to recompute: {e:#}",
                        entry.req.id
                    );
                    // fall through to the prefill + replay path below
                }
            }
        }
        let mut policy = match make_policy(&entry.req.policy) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("req {}: {e:#}", entry.req.id);
                let out = Self::error_output(&entry.req);
                self.emit(entry.req.id, SeqEvent::Finished(out));
                return AdmitOutcome::Failed;
            }
        };
        // Chunked prefill: a prompt longer than the chunk size streams in
        // across rounds instead of blocking this one. The begin call
        // already processes the first chunk; More parks the job in
        // `prefilling` (it occupies the concurrency slot admission just
        // granted), Done means one chunk covered the whole prompt and the
        // sequence admits normally.
        if self.cfg.prefill_chunk > 0 && entry.req.prompt.len() > self.cfg.prefill_chunk {
            match self.backend.prefill_begin(
                &self.arena,
                &entry.req.prompt,
                entry.req.budget,
                policy,
                entry.plan.as_ref(),
                self.cfg.prefill_chunk,
            ) {
                Ok(Some(PrefillStep::More(job))) => {
                    log::debug!(
                        "req {}: chunked prefill started ({} prompt tokens, {} per round)",
                        entry.req.id,
                        entry.req.prompt.len(),
                        self.cfg.prefill_chunk
                    );
                    self.prefilling.push((entry, job));
                    return AdmitOutcome::Chunking;
                }
                Ok(Some(PrefillStep::Done { seq, logits })) => {
                    let hit_blocks = self.admit_ready(entry, seq, logits);
                    return AdmitOutcome::Admitted { restored: false, hit_blocks };
                }
                Ok(Some(PrefillStep::OutOfMemory)) => {
                    return AdmitOutcome::OutOfMemory(entry);
                }
                Ok(None) => {
                    // backend cannot chunk: fall through to the one-shot
                    // path with a rebuilt policy (begin consumed the box;
                    // make_policy succeeded above, so it succeeds now)
                    policy = match make_policy(&entry.req.policy) {
                        Ok(p) => p,
                        Err(_) => return AdmitOutcome::Failed,
                    };
                }
                Err(e) => {
                    log::warn!("req {}: chunked prefill failed: {e:#}", entry.req.id);
                    let out = Self::error_output(&entry.req);
                    self.emit(entry.req.id, SeqEvent::Finished(out));
                    return AdmitOutcome::Failed;
                }
            }
        }
        let prefilled = self.backend.prefill_planned(
            &self.arena,
            &entry.req.prompt,
            entry.req.budget,
            policy,
            entry.plan.as_ref(),
        );
        match prefilled {
            Ok(Prefilled::Ready { seq, logits }) => {
                let hit_blocks = self.admit_ready(entry, seq, logits);
                AdmitOutcome::Admitted { restored: false, hit_blocks }
            }
            Ok(Prefilled::OutOfMemory) => AdmitOutcome::OutOfMemory(entry),
            Err(e) => {
                log::warn!("req {}: prefill failed: {e:#}", entry.req.id);
                let out = Self::error_output(&entry.req);
                self.emit(entry.req.id, SeqEvent::Finished(out));
                AdmitOutcome::Failed
            }
        }
    }

    /// Install a freshly prefilled sequence into the running set —
    /// identical bookkeeping whether the prefill was one-shot
    /// (`prefill_planned`) or the final chunk of a chunked prefill
    /// (`prefill_advance` returning [`PrefillStep::Done`]): TTFT stops at
    /// the moment the sequence goes live either way. Returns the
    /// prefill's prefix-index hit count for the caller's accounting.
    fn admit_ready(
        &mut self,
        entry: QueueEntry<B::PrefillPlan>,
        seq: B::Seq,
        logits: Vec<f32>,
    ) -> u64 {
        let now = Instant::now();
        if entry.preemptions == 0 && entry.retries == 0 {
            // first admission only: recompute-on-readmission must
            // not double count useful prompt work (a victim can be
            // preempted — or suspended for a transient-error
            // retry — before producing anything, so an empty
            // resume list does not imply a first admission)
            self.total_prompt_tokens += entry.req.prompt.len() as u64;
            // The first generated token exists the moment prefill
            // returns — TTFT stops here (vLLM semantics).
            let ttft_s = now.duration_since(entry.enqueued).as_secs_f64();
            self.emit_stream(&entry.req, SeqEvent::Prefilled { ttft_s });
        } else {
            // recompute readmission: replay will rebuild the
            // produced tokens without re-emitting them
            self.emit_stream(&entry.req, SeqEvent::Resumed);
        }
        let serial = self.admit_counter.fetch_add(1, Ordering::Relaxed) + 1;
        // a fresh cache's counters cover exactly this prefill
        let hit_blocks = B::cache(&seq).stats.prefix_hit_blocks;
        let cow_seen = B::cache(&seq).stats.cow_copies;
        self.running.push(Inflight {
            next_token: argmax(&logits),
            // A preempted request keeps its original first-token
            // time.
            first_token_at: Some(entry.first_token_at.unwrap_or(now)),
            enqueued: entry.enqueued,
            decode_seconds: entry.decode_seconds,
            produced: entry.resume,
            fed: 0,
            admit_serial: serial,
            preemptions: entry.preemptions,
            swaps: entry.swaps,
            cow_seen,
            deadline_at: entry.deadline_at,
            retries: entry.retries,
            fault_streak: entry.fault_streak,
            req: entry.req,
            seq,
        });
        hit_blocks
    }

    // ---- multi-worker engine hooks (crate-private) --------------------
    //
    // The engine owns one scheduler per worker thread; these are the only
    // extra touch points it needs. They are all no-ops / None in
    // single-worker use.

    /// Install the engine's pressure hook (global running visibility +
    /// the reclaim channel). Engine-only.
    pub(crate) fn set_pressure_hook(&mut self, hook: PressureHook) {
        self.hook = Some(hook);
    }

    /// Sequences running on OTHER workers right now (0 without a hook).
    fn others_running(&self) -> usize {
        self.hook.as_ref().map(|h| h.others_running()).unwrap_or(0)
    }

    /// Post one reclaim request to the engine's pressure channel (no-op
    /// without a hook).
    fn post_pressure(&self) {
        if let Some(h) = &self.hook {
            h.post();
        }
    }

    /// The `(priority, admit_serial)` victim key of this worker's local
    /// preemption candidate ([`Scheduler::victim_idx`]'s choice), or
    /// `None` with nothing running. The engine compares keys across
    /// workers under `(priority, Reverse(serial))` to find the GLOBAL
    /// victim — serials come from the shared counter, so the comparison
    /// is meaningful across workers.
    pub fn min_victim_key(&self) -> Option<(Priority, u64)> {
        self.running
            .iter()
            .map(|f| (f.req.priority, f.admit_serial))
            .min_by_key(|&(p, s)| (p, std::cmp::Reverse(s)))
    }

    /// Preempt this worker's local victim into the shared swap pool —
    /// the engine calls this on the worker that owns the GLOBAL victim
    /// when another worker posted reclaim pressure. Returns `false` with
    /// nothing running.
    pub fn preempt_min(&mut self) -> bool {
        if self.running.is_empty() {
            return false;
        }
        let victim = self.victim_idx();
        self.preempt(victim);
        true
    }

    /// Pop one steal candidate from the BACK of the lowest-priority
    /// non-empty bucket: the entry an idle worker donates to a thief. The
    /// tail is the entry this worker would reach LAST, so stealing it
    /// never reorders anyone's head-of-line progress. Entries carrying a
    /// step deadline are skipped — deadlines are absolute against the
    /// owning worker's round clock and would shift meaning on another
    /// worker's clock.
    pub(crate) fn steal_tail(&mut self) -> Option<QueueEntry<B::PrefillPlan>> {
        for b in (0..self.queues.len()).rev() {
            let Some(pos) = self.queues[b].iter().rposition(|e| e.deadline_at.is_none())
            else {
                continue;
            };
            return self.queues[b].remove(pos);
        }
        None
    }

    /// Accept a stolen (or engine-placed) queue entry into this worker's
    /// bucket tail. Claim/plan memos, resume tokens and any parked swap
    /// snapshot all stay valid across the move: the arena (prefix epoch)
    /// and swap pool are shared engine-wide.
    pub(crate) fn inject(&mut self, entry: QueueEntry<B::PrefillPlan>) {
        let bucket = Self::bucket(entry.req.priority);
        self.queues[bucket].push_back(entry);
    }

    /// Move one queue-tail entry from this scheduler to `other` — the
    /// work-stealing handoff ([`Scheduler::steal_tail`] + inject) as one
    /// public operation, for embedders running their own worker loops
    /// (and for the hot-path bench that pins the handoff cost). Both
    /// schedulers must share the same arena/swap pool (`with_shared`) or
    /// the moved entry's memos and snapshots are meaningless. Returns
    /// `false` when nothing here is stealable.
    pub fn donate_to(&mut self, other: &mut Scheduler<B>) -> bool {
        match self.steal_tail() {
            Some(entry) => {
                other.inject(entry);
                true
            }
            None => false,
        }
    }

    /// Index of the preemption victim: the LOWEST-priority running
    /// sequence, youngest (most recently admitted) within that class —
    /// low-priority work always pays for memory pressure before
    /// higher-priority work, and within a class the youngest wastes the
    /// least completed work.
    fn victim_idx(&self) -> usize {
        self.running
            .iter()
            .enumerate()
            .min_by_key(|(_, f)| (f.req.priority, std::cmp::Reverse(f.admit_serial)))
            .map(|(i, _)| i)
            .expect("victim_idx on empty running set")
    }

    /// Evict a running sequence under MEMORY pressure. See
    /// [`Scheduler::suspend`].
    fn preempt(&mut self, idx: usize) {
        self.suspend(idx, false);
    }

    /// Suspend a running sequence: park its snapshot in the swap pool when
    /// the backend can produce one (swap-to-host), free its blocks, and
    /// requeue it at the queue front. The produced tokens ride along in
    /// the queue entry either way, so a snapshot later LRU-dropped from
    /// the pool degrades to the recompute path without losing work.
    ///
    /// `retry` distinguishes a TRANSIENT-decode-error retry (counts one
    /// retry against the request's budget and the `fault_retries`
    /// aggregate) from a memory-pressure preemption (counts a
    /// preemption). Both readmit identically — restore-or-replay is
    /// bit-identical either way, which is exactly why transient recovery
    /// reuses this machinery.
    fn suspend(&mut self, idx: usize, retry: bool) {
        let f = self.running.remove(idx);
        if retry {
            self.fault_retries += 1;
        } else {
            self.preemptions += 1;
        }
        let n_blocks = B::cache(&f.seq).n_blocks();
        // fold the victim's not-yet-counted copy-on-write work into the
        // aggregate NOW: the victim misses the post-reservation delta
        // pass, and a later restore re-seeds its watermark from the
        // snapshot (a recompute readmission starts a fresh cache at 0)
        self.cow_copies += B::cache(&f.seq).stats.cow_copies - f.cow_seen;
        let Inflight {
            req,
            seq,
            enqueued,
            first_token_at,
            decode_seconds,
            produced,
            fed,
            preemptions,
            swaps,
            next_token,
            deadline_at,
            retries,
            fault_streak,
            ..
        } = f;
        let mut swapped = false;
        if self.swap.capacity_bytes() > 0 {
            if let Some(snap) = self.backend.snapshot(&seq) {
                swapped = self.swap.insert(req.id, snap);
            }
        }
        if swapped {
            self.swap_outs += 1;
        }
        self.emit_stream(&req, SeqEvent::Preempted { swap: swapped });
        log::info!(
            "req {}: {} (freeing {} blocks, {})",
            req.id,
            if retry {
                "suspended to retry a transient decode error"
            } else {
                "preempted under memory pressure"
            },
            n_blocks,
            if swapped {
                "snapshot swapped to host"
            } else {
                "produced tokens kept for replay"
            }
        );
        drop(seq); // returns every block the victim held to the arena
        let bucket = Self::bucket(req.priority);
        self.queues[bucket].push_front(QueueEntry {
            req,
            enqueued,
            resume: produced,
            first_token_at,
            decode_seconds,
            preemptions: if retry { preemptions } else { preemptions + 1 },
            swaps,
            swap_fed: fed,
            next_token,
            deadline_at,
            claim: None,
            // the plan is request-pure and a readmission prefill replays
            // the same prompt, but the claim scan re-derives it anyway:
            // keeping both memos in lockstep keeps invalidation trivial
            plan: None,
            retries: if retry { retries + 1 } else { retries },
            fault_streak,
        });
    }

    /// Retire a sequence with its output. `forced` overrides the natural
    /// finish reason (errors, deadline expiry); `None` derives it from
    /// the stop set / length.
    fn retire(&mut self, f: Inflight<B::Seq>, forced: Option<FinishReason>) {
        let ttft = f
            .first_token_at
            .map(|t| t.duration_since(f.enqueued).as_secs_f64())
            .unwrap_or(0.0);
        let n = f.produced.len();
        let tpot = if n > 1 {
            f.decode_seconds / (n - 1).max(1) as f64
        } else {
            f.decode_seconds
        };
        self.ttft.add(ttft * 1e3);
        self.tpot.add(tpot * 1e3);
        let finish = match forced {
            Some(reason) => reason,
            None => {
                if f.produced.last().is_some_and(|&t| f.req.is_stop(t)) {
                    FinishReason::Eos
                } else {
                    FinishReason::MaxTokens
                }
            }
        };
        let cache = B::cache(&f.seq);
        let live_cache_tokens = cache.live_tokens();
        let mut cache_stats = cache.stats.clone();
        cache_stats.preemptions = f.preemptions as u64;
        cache_stats.swaps = f.swaps as u64;
        cache_stats.retries = f.retries as u64;
        let arena_stats = self.arena.stats();
        cache_stats.peak_arena_blocks = arena_stats.peak_used as u64;
        cache_stats.arena_lock_acquisitions = arena_stats.lock_acquisitions;
        cache_stats.arena_contended_acquisitions = arena_stats.contended_acquisitions;
        cache_stats.arena_cache_refills = arena_stats.cache_refills;
        cache_stats.arena_cache_drains = arena_stats.cache_drains;
        // nothing should be parked for a running sequence; be thorough so
        // an error retirement can never strand host swap bytes
        self.swap.discard(f.req.id);
        let out = RequestOutput {
            id: f.req.id,
            tokens: f.produced,
            policy: f.req.policy.clone(),
            finish,
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_len: f.req.prompt.len(),
            live_cache_tokens,
            preemptions: f.preemptions,
            swaps: f.swaps,
            retries: f.retries,
            cache_stats,
        };
        self.emit(out.id, SeqEvent::Finished(out));
        // f.seq drops here, returning its blocks to the arena
    }
}

impl Scheduler<crate::runtime::SimBackend> {
    /// Scheduler over the always-built deterministic sim backend.
    pub fn new_sim(cfg: SchedConfig) -> Self {
        let backend = crate::runtime::SimBackend::new(cfg.page_size);
        Self::with_backend(backend, cfg)
    }
}

impl Scheduler<crate::runtime::FaultyBackend<crate::runtime::SimBackend>> {
    /// Scheduler over the sim backend wrapped in the deterministic
    /// fault-injection layer (`schedule --faults`, chaos tests).
    pub fn new_sim_faulty(cfg: SchedConfig, plan: crate::runtime::FaultPlan) -> Self {
        let backend = crate::runtime::FaultyBackend::new(
            crate::runtime::SimBackend::new(cfg.page_size),
            plan,
        );
        Self::with_backend(backend, cfg)
    }
}

#[cfg(feature = "xla")]
impl<'e> Scheduler<crate::runtime::ModelRunner<'e>> {
    /// Scheduler over the PJRT runtime (historical constructor).
    pub fn new(engine: &'e crate::runtime::Engine, cfg: SchedConfig) -> Result<Self> {
        let runner = crate::runtime::ModelRunner::new(engine, &cfg.model, cfg.page_size)?;
        Ok(Self::with_backend(runner, cfg))
    }
}
