//! The decode-backend abstraction the scheduler drives.
//!
//! The scheduler owns request lifecycle, the shared [`BlockManager`] arena,
//! batched decode rounds and preemption; a backend owns model execution.
//! Two implementations exist:
//!
//!   * [`crate::runtime::SimBackend`] — always built; a deterministic toy
//!     LM over the REAL cache/eviction machinery, so the whole scheduling
//!     stack is exercised by plain `cargo test`;
//!   * `crate::runtime::ModelRunner` (behind the `xla` feature) — the PJRT
//!     runtime, dispatching one padded batched decode graph per round when
//!     the artifact set provides one.

use anyhow::Result;

use crate::eviction::EvictionPolicy;
use crate::kvcache::{BlockManager, SeqCache};

/// Outcome of a prefill attempt against the shared arena.
pub enum Prefilled<S> {
    /// Prompt processed; `logits` are the last-position logits (the first
    /// generated token exists as soon as this returns — TTFT stops here).
    Ready { seq: S, logits: Vec<f32> },
    /// The arena cannot hold the packed prompt right now. Not an error:
    /// the scheduler requeues the request and retries once capacity frees.
    OutOfMemory,
}

pub trait DecodeBackend {
    /// Backend-owned per-sequence state (cache + model-side buffers).
    type Seq;

    /// Run the prompt, apply prefill eviction, pack the survivors into a
    /// paged cache allocated from `arena`.
    fn prefill(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Result<Prefilled<Self::Seq>>;

    fn cache(seq: &Self::Seq) -> &SeqCache;

    fn cache_mut(seq: &mut Self::Seq) -> &mut SeqCache;

    /// Migrate `seq` to a larger device bucket (its serialization bucket
    /// is full). Must strictly enlarge the bucket or error.
    fn grow_bucket(&mut self, seq: &mut Self::Seq) -> Result<()>;

    /// One decode step for every `(sequence, token-to-feed)` entry — the
    /// scheduler issues exactly one call per round for the whole running
    /// set. Every entry has a write slot reserved by the scheduler
    /// beforehand. Returns next-token logits per entry, same order;
    /// per-entry errors let the scheduler retire one sequence without
    /// failing the round.
    fn decode_batch(&mut self, batch: &mut [(&mut Self::Seq, u32)]) -> Vec<Result<Vec<f32>>>;
}
