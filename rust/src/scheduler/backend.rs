//! The decode-backend abstraction the scheduler drives.
//!
//! The scheduler owns request lifecycle, the shared [`BlockManager`] arena,
//! batched decode rounds and preemption; a backend owns model execution.
//! Two implementations exist:
//!
//!   * [`crate::runtime::SimBackend`] — always built; a deterministic toy
//!     LM over the REAL cache/eviction machinery, so the whole scheduling
//!     stack is exercised by plain `cargo test`;
//!   * `crate::runtime::ModelRunner` (behind the `xla` feature) — the PJRT
//!     runtime, dispatching one padded batched decode graph per round when
//!     the artifact set provides one.

use anyhow::Result;

use super::request::Request;
use crate::eviction::{make_policy, EvictionPolicy};
use crate::kvcache::{BlockAlloc, BlockManager, SeqCache};

/// Per-sequence decode failure taxonomy — what the scheduler's recovery
/// machinery keys on.
///
/// A [`BackendError::Transient`] decode error (an injected fault, a
/// device hiccup, a retriable runtime error) does NOT retire the request:
/// the scheduler suspends it through the same preemption/readmission
/// machinery memory pressure uses (recompute-and-replay, so the recovered
/// output stays bit-identical to a fault-free run), bounded by a
/// per-request retry budget and a consecutive-failure circuit breaker. A
/// [`BackendError::Terminal`] error retires the request immediately with
/// [`super::request::FinishReason::Error`].
pub enum BackendError {
    /// Retriable: the sequence state is intact (or recoverable by
    /// replay); the scheduler may suspend and readmit.
    Transient(anyhow::Error),
    /// Unrecoverable for this sequence: retire it as an error.
    Terminal(anyhow::Error),
}

impl BackendError {
    pub fn transient(e: anyhow::Error) -> BackendError {
        BackendError::Transient(e)
    }

    pub fn terminal(e: anyhow::Error) -> BackendError {
        BackendError::Terminal(e)
    }

    pub fn is_transient(&self) -> bool {
        matches!(self, BackendError::Transient(_))
    }

    pub fn inner(&self) -> &anyhow::Error {
        match self {
            BackendError::Transient(e) | BackendError::Terminal(e) => e,
        }
    }
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Transient(e) => write!(f, "transient: {e}"),
            BackendError::Terminal(e) => write!(f, "terminal: {e}"),
        }
    }
}

impl std::fmt::Debug for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Transient(e) => write!(f, "Transient({e:#})"),
            BackendError::Terminal(e) => write!(f, "Terminal({e:#})"),
        }
    }
}

/// Arena blocks a fresh prefill of `req` claims, ignoring any
/// prefix-cache state: the per-policy resident prompt
/// ([`EvictionPolicy::prefill_resident`] — `FullCache` keeps the whole
/// prompt regardless of budget) packed into pages. The fallback estimate
/// behind [`DecodeBackend::prefill_claim`].
pub fn static_prefill_claim(req: &Request, page_size: usize) -> usize {
    let resident = match make_policy(&req.policy) {
        Ok(p) => p.prefill_resident(req.prompt.len(), req.budget),
        // an unknown policy fails at admission anyway; charge the pack
        Err(_) => req.prompt.len().min(req.budget),
    };
    (resident + page_size - 1) / page_size
}

/// Memoized admission-time claim estimate for one queued request.
///
/// [`DecodeBackend::prefill_claim`] can be O(prompt) — the sim backend
/// replays the policy's prefill scorer AND the prefix-index hash chain —
/// and the admission gate may retry the same head-of-queue entry every
/// round while the arena sits above its low watermark. The scheduler
/// caches the estimate on the queue entry keyed by the arena's
/// [`crate::kvcache::BlockManager::prefix_epoch`]: the estimate only
/// depends on the (immutable) request and the prefix-index contents, so
/// an unchanged epoch means the cached claim is still exact and the
/// retry skips the recompute entirely.
#[derive(Debug, Clone, Copy)]
pub struct ClaimMemo {
    epoch: u64,
    blocks: usize,
}

impl ClaimMemo {
    /// Record `blocks` as computed against the arena's CURRENT prefix
    /// index.
    pub fn record(arena: &BlockManager, blocks: usize) -> ClaimMemo {
        ClaimMemo { epoch: arena.prefix_epoch(), blocks }
    }

    /// The memoized claim, if the prefix index has not changed since it
    /// was recorded.
    pub fn get(&self, arena: &BlockManager) -> Option<usize> {
        (self.epoch == arena.prefix_epoch()).then_some(self.blocks)
    }
}

/// Outcome of a prefill attempt against the shared arena.
pub enum Prefilled<S> {
    /// Prompt processed; `logits` are the last-position logits (the first
    /// generated token exists as soon as this returns — TTFT stops here).
    Ready { seq: S, logits: Vec<f32> },
    /// The arena cannot hold the packed prompt right now. Not an error:
    /// the scheduler requeues the request and retries once capacity frees.
    OutOfMemory,
}

/// Outcome of one chunk of an incremental (chunked) prefill.
///
/// Chunked prefill spreads a huge prompt's compute across several
/// scheduler rounds instead of head-of-line blocking a decode round: each
/// round the scheduler feeds one `prefill_chunk`-sized slice of prompt
/// through [`DecodeBackend::prefill_advance`] and still runs its normal
/// batched decode for the sequences already generating.
pub enum PrefillStep<S, J> {
    /// Prompt tokens remain; call [`DecodeBackend::prefill_advance`] again
    /// next round with the carried job.
    More(J),
    /// Final chunk processed: the sequence is live and `logits` are the
    /// last-position logits, exactly as [`Prefilled::Ready`] would have
    /// returned them for a one-shot prefill of the same request.
    Done { seq: S, logits: Vec<f32> },
    /// The arena cannot hold the packed prompt right now (claim happens at
    /// completion). Not an error: the scheduler requeues the request.
    OutOfMemory,
}

/// Outcome of a swap-restore attempt against the shared arena.
pub enum Restored<S> {
    /// Sequence rebuilt from the host snapshot; decode continues exactly
    /// where it stopped (no recompute, no replay).
    Ready(S),
    /// The arena cannot hold the snapshot's blocks right now. Not an
    /// error: the scheduler keeps the snapshot and retries later.
    OutOfMemory,
}

/// What the scheduler's bounded host-side swap pool accounts for a
/// backend snapshot.
pub trait HostSnapshot {
    /// Approximate host bytes the snapshot pins while parked in the pool.
    fn host_bytes(&self) -> usize;

    /// Arena blocks a restore will claim — the admission estimate for a
    /// swapped victim (exact, unlike the prompt-based estimate for fresh
    /// admissions).
    fn arena_blocks(&self) -> usize;
}

/// Placeholder snapshot type for backends that cannot swap to host:
/// `snapshot()` always returns `None`, so `restore()` is unreachable and
/// the scheduler uses recompute-on-readmission for every victim.
pub struct NoSwap;

impl HostSnapshot for NoSwap {
    fn host_bytes(&self) -> usize {
        0
    }

    fn arena_blocks(&self) -> usize {
        0
    }
}

pub trait DecodeBackend {
    /// Backend-owned per-sequence state (cache + model-side buffers).
    type Seq;

    /// Host-side snapshot of a suspended sequence (swap-to-host). Use
    /// [`NoSwap`] when the backend cannot produce one.
    type Snapshot: HostSnapshot;

    /// Backend-opaque artifact of the admission-time claim scan that a
    /// later prefill of the SAME request can reuse instead of recomputing
    /// (the sim backend stashes the policy's kept-entry stream here — the
    /// exact scan `prefill_claim` already ran to price the admission).
    /// Use `()` when the claim computes nothing worth keeping. The
    /// artifact depends only on the immutable request (prompt, budget,
    /// policy), so the scheduler keeps it on the queue entry — next to
    /// the epoch-keyed [`ClaimMemo`] — for the entry's whole queued life.
    type PrefillPlan;

    /// Carried state of an in-progress chunked prefill between rounds.
    /// Use `()` for backends that do not support chunking
    /// ([`DecodeBackend::prefill_begin`] then returns `Ok(None)` and the
    /// scheduler falls back to the one-shot path).
    type PrefillJob;

    /// Enable or disable the backend's prefix cache (refcounted shared
    /// prompt pages). Called once by the scheduler from its config;
    /// backends without a prefix cache ignore it.
    fn set_prefix_cache(&mut self, _enabled: bool) {}

    /// Arena blocks a fresh prefill of `req` would claim right NOW — the
    /// scheduler's admission charge. Prefix-caching backends subtract the
    /// leading prefix-index hits (those pages are pinned by refcount, not
    /// re-claimed); the default is the policy-aware packed-prompt
    /// estimate. Exactness is not required: admission is optimistic and
    /// prefill itself is fallible.
    fn prefill_claim(&self, _arena: &BlockManager, req: &Request, page_size: usize) -> usize {
        static_prefill_claim(req, page_size)
    }

    /// [`DecodeBackend::prefill_claim`] plus the reusable scan artifact:
    /// backends whose claim estimate already does the prefill policy scan
    /// return it here so the scheduler can hand it back to
    /// [`DecodeBackend::prefill_planned`] and the admitted prefill skips
    /// the recompute. The default computes the plain claim and no
    /// artifact.
    fn prefill_claim_planned(
        &self,
        arena: &BlockManager,
        req: &Request,
        page_size: usize,
    ) -> (usize, Option<Self::PrefillPlan>) {
        (self.prefill_claim(arena, req, page_size), None)
    }

    /// [`DecodeBackend::prefill`] with an optional claim-scan artifact
    /// from [`DecodeBackend::prefill_claim_planned`] for the same request.
    /// Backends that honor the plan MUST produce a bit-identical sequence
    /// either way — the plan is a memo, not an input. The default ignores
    /// it.
    fn prefill_planned(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
        _plan: Option<&Self::PrefillPlan>,
    ) -> Result<Prefilled<Self::Seq>> {
        self.prefill(arena, prompt, budget, policy)
    }

    /// Begin a chunked prefill: process the first `chunk` prompt tokens
    /// and carry the rest as a [`PrefillStep::More`] job the scheduler
    /// advances on subsequent rounds via
    /// [`DecodeBackend::prefill_advance`]. Arena pages are claimed when
    /// the FINAL chunk completes (claim-at-completion), so an in-progress
    /// job holds no arena blocks and aborting one (deadline, cancel,
    /// memory pressure) is free. A backend that honors this MUST produce
    /// a sequence bit-identical to [`DecodeBackend::prefill_planned`] of
    /// the same request — chunking slices compute, never content. The
    /// default returns `Ok(None)`: chunking unsupported, scheduler uses
    /// the one-shot path.
    fn prefill_begin(
        &mut self,
        _arena: &BlockManager,
        _prompt: &[u32],
        _budget: usize,
        _policy: Box<dyn EvictionPolicy>,
        _plan: Option<&Self::PrefillPlan>,
        _chunk: usize,
    ) -> Result<Option<PrefillStep<Self::Seq, Self::PrefillJob>>> {
        Ok(None)
    }

    /// Advance an in-progress chunked prefill by up to `chunk` prompt
    /// tokens. Only ever called with a job returned by
    /// [`DecodeBackend::prefill_begin`] / a previous `prefill_advance`,
    /// so backends that never return one can leave the default.
    fn prefill_advance(
        &mut self,
        _job: Self::PrefillJob,
        _chunk: usize,
    ) -> Result<PrefillStep<Self::Seq, Self::PrefillJob>> {
        unreachable!("prefill_advance called on a backend that never returns PrefillStep::More")
    }

    /// Make `seq` safe for this round's decode step, called during
    /// reservation BEFORE the batched decode: a policy that hole-punches
    /// tokens inside existing pages must not write a shared
    /// (refcount > 1) page in place, so its shared pages are
    /// copied-on-write here — where an [`BlockAlloc::ArenaDry`] still has
    /// a remedy (the scheduler preempts and retries). The default is a
    /// no-op for backends without shared pages.
    fn prepare_round(&mut self, _seq: &mut Self::Seq) -> BlockAlloc {
        BlockAlloc::Ready
    }

    /// Run the prompt, apply prefill eviction, pack the survivors into a
    /// paged cache allocated from `arena`.
    fn prefill(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Result<Prefilled<Self::Seq>>;

    fn cache(seq: &Self::Seq) -> &SeqCache;

    fn cache_mut(seq: &mut Self::Seq) -> &mut SeqCache;

    /// Migrate `seq` to a larger device bucket (its serialization bucket
    /// is full). Must strictly enlarge the bucket or error.
    fn grow_bucket(&mut self, seq: &mut Self::Seq) -> Result<()>;

    /// Capture everything needed to rebuild `seq` later WITHOUT
    /// recompute — cache metadata, eviction-policy state, model-side
    /// continuation state. `None` when this backend cannot swap (e.g. the
    /// PJRT runner, whose K/V lives on device); the scheduler then falls
    /// back to recompute-on-readmission for this victim.
    fn snapshot(&self, seq: &Self::Seq) -> Option<Self::Snapshot>;

    /// Rebuild a sequence from a host snapshot, claiming fresh blocks from
    /// `arena`. Must claim nothing on [`Restored::OutOfMemory`].
    fn restore(
        &mut self,
        arena: &BlockManager,
        snap: &Self::Snapshot,
    ) -> Result<Restored<Self::Seq>>;

    /// The per-sequence attention-feedback channel: accumulated attention
    /// mass per ORIGINAL position, consumed by feedback-aware eviction
    /// policies ([`crate::eviction::EvictionPolicy::wants_feedback`]).
    /// The default — and the PJRT runner, which ships no kernel
    /// modifications and has no per-position attention readout — returns
    /// `None`; such policies then fall back to their score-channel proxy.
    /// Backends should only assemble the vector (an O(live-tokens) pass)
    /// for sequences whose policy asks for it.
    fn attention_feedback(&self, _seq: &Self::Seq) -> Option<crate::eviction::AttnFeedback> {
        None
    }

    /// How many leading blocks of `prompt` the arena's prefix index would
    /// serve by reference RIGHT NOW — the autotuner's shared-prefix-depth
    /// probe (`scheduler::autotune`). Purely a read: no pages are claimed
    /// or pinned. Backends without a content-addressed prefill pack (or
    /// with the prefix cache off) report 0, which the autotuner treats as
    /// "no shared prefix".
    fn shared_prefix_depth(&self, _arena: &BlockManager, _prompt: &[u32]) -> usize {
        0
    }

    /// One decode step for every `(sequence, token-to-feed)` entry — the
    /// scheduler issues exactly one call per round for the whole running
    /// set. Every entry has a write slot reserved by the scheduler
    /// beforehand. Returns next-token logits per entry, same order;
    /// per-entry [`BackendError`]s let the scheduler retry (transient) or
    /// retire (terminal) one sequence without failing the round.
    fn decode_batch(
        &mut self,
        batch: &mut [(&mut Self::Seq, u32)],
    ) -> Vec<std::result::Result<Vec<f32>, BackendError>>;
}
