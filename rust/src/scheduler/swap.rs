//! Bounded host-side pool of swapped-out sequence snapshots.
//!
//! When the scheduler preempts a victim it prefers parking the victim's
//! full state here (swap-to-host) over discarding it: readmission then
//! restores the snapshot instead of re-prefilling the prompt and replaying
//! every produced token — the recompute cost vLLM's swapping path avoids.
//!
//! The pool is byte-accounted and LRU-capped: inserting past the cap drops
//! the OLDEST parked snapshots first (their victims transparently fall
//! back to the recompute path, which is always kept valid — the queue
//! entry retains the produced tokens), so host memory for swap is a hard
//! bound, not a hope.
//!
//! Snapshots are pure host-side copies: they pin NO arena blocks, so with
//! refcounted prefix sharing an LRU drop (or discard) of a parked
//! snapshot can never free a physical page another live sequence still
//! shares — the victim's own claims were already released by refcount
//! when it was preempted. Asserted in `tests/prefix_cache.rs`.

use std::collections::VecDeque;

use super::backend::HostSnapshot;

/// Byte-capped LRU store of per-request snapshots, keyed by request id.
#[derive(Debug)]
pub struct SwapPool<S> {
    cap_bytes: usize,
    used_bytes: usize,
    /// Insertion order, oldest first — the front is the next LRU victim.
    entries: VecDeque<(u64, usize, S)>,
    dropped: u64,
}

impl<S: HostSnapshot> SwapPool<S> {
    /// A pool with `cap_bytes == 0` is disabled: every insert fails and
    /// the scheduler preempts by recompute only.
    pub fn new(cap_bytes: usize) -> Self {
        SwapPool { cap_bytes, used_bytes: 0, entries: VecDeque::new(), dropped: 0 }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cap_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Snapshots LRU-dropped (or displaced by a re-insert for the same
    /// request) never restored — their victims fell back to recompute.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.iter().any(|(i, _, _)| *i == id)
    }

    /// Arena blocks the parked snapshot for `id` would claim on restore —
    /// the scheduler's admission estimate for a swapped victim.
    pub fn arena_blocks_of(&self, id: u64) -> Option<usize> {
        self.entries
            .iter()
            .find(|(i, _, _)| *i == id)
            .map(|(_, _, s)| s.arena_blocks())
    }

    /// Park a snapshot, evicting oldest entries until it fits. Returns
    /// `false` — and stores nothing — when the snapshot alone exceeds the
    /// pool cap (or the pool is disabled); the caller falls back to
    /// recompute. A snapshot already parked for the same id is replaced
    /// (counted in `dropped` only when a DIFFERENT id is evicted).
    pub fn insert(&mut self, id: u64, snap: S) -> bool {
        self.remove(id);
        let bytes = snap.host_bytes();
        if self.cap_bytes == 0 || bytes > self.cap_bytes {
            return false;
        }
        while self.used_bytes + bytes > self.cap_bytes {
            let (_, b, _) = self.entries.pop_front().expect("byte accounting broken");
            self.used_bytes -= b;
            self.dropped += 1;
        }
        self.used_bytes += bytes;
        self.entries.push_back((id, bytes, snap));
        true
    }

    /// Remove and return the snapshot for `id` (readmission restore).
    pub fn take(&mut self, id: u64) -> Option<S> {
        let pos = self.entries.iter().position(|(i, _, _)| *i == id)?;
        let (_, bytes, snap) = self.entries.remove(pos).expect("position just found");
        self.used_bytes -= bytes;
        Some(snap)
    }

    /// Drop the snapshot for `id` if parked (e.g. its request was
    /// rejected or cancelled). Not counted as an LRU drop; returns
    /// whether a snapshot was actually dropped.
    pub fn discard(&mut self, id: u64) -> bool {
        self.remove(id)
    }

    fn remove(&mut self, id: u64) -> bool {
        if let Some(pos) = self.entries.iter().position(|(i, _, _)| *i == id) {
            let (_, bytes, _) = self.entries.remove(pos).expect("position just found");
            self.used_bytes -= bytes;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test snapshot with a settable footprint.
    struct Fake(usize);

    impl HostSnapshot for Fake {
        fn host_bytes(&self) -> usize {
            self.0
        }

        fn arena_blocks(&self) -> usize {
            self.0 / 100
        }
    }

    #[test]
    fn insert_take_roundtrip_accounts_bytes() {
        let mut p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(500)));
        assert_eq!(p.used_bytes(), 900);
        assert_eq!(p.arena_blocks_of(1), Some(4));
        assert!(p.take(1).is_some());
        assert_eq!(p.used_bytes(), 500);
        assert!(p.take(1).is_none(), "take removes");
        assert_eq!(p.dropped(), 0);
    }

    #[test]
    fn cap_evicts_oldest_first() {
        let mut p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(400)));
        // 400 + 400 + 600 > 1000: both elder snapshots must go
        assert!(p.insert(3, Fake(600)));
        assert_eq!(p.dropped(), 2);
        assert!(!p.contains(1) && !p.contains(2));
        assert!(p.contains(3));
        assert_eq!(p.used_bytes(), 600);
    }

    #[test]
    fn partial_eviction_keeps_newer_entries() {
        let mut p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(400)));
        assert!(p.insert(3, Fake(300)));
        assert_eq!(p.dropped(), 1, "only the oldest (1) needed to go");
        assert!(!p.contains(1));
        assert!(p.contains(2) && p.contains(3));
    }

    #[test]
    fn oversized_or_disabled_insert_fails_cleanly() {
        let mut p = SwapPool::new(100);
        assert!(!p.insert(1, Fake(101)), "snapshot bigger than the pool");
        assert_eq!(p.len(), 0);
        let mut off: SwapPool<Fake> = SwapPool::new(0);
        assert!(!off.insert(1, Fake(0)), "disabled pool parks nothing");
    }

    #[test]
    fn reinsert_same_id_replaces_without_drop() {
        let mut p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(600)));
        assert!(p.insert(1, Fake(700)), "own entry is displaced, not counted");
        assert_eq!(p.dropped(), 0);
        assert_eq!(p.used_bytes(), 700);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn discard_is_silent() {
        let mut p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(500)));
        assert!(p.discard(1));
        assert!(!p.discard(2), "absent: no-op");
        assert!(p.is_empty());
        assert_eq!(p.dropped(), 0);
    }
}
