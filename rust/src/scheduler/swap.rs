//! Bounded host-side pool of swapped-out sequence snapshots.
//!
//! When the scheduler preempts a victim it prefers parking the victim's
//! full state here (swap-to-host) over discarding it: readmission then
//! restores the snapshot instead of re-prefilling the prompt and replaying
//! every produced token — the recompute cost vLLM's swapping path avoids.
//!
//! The pool is byte-accounted and LRU-capped: inserting past the cap drops
//! the OLDEST parked snapshots first (their victims transparently fall
//! back to the recompute path, which is always kept valid — the queue
//! entry retains the produced tokens), so host memory for swap is a hard
//! bound, not a hope.
//!
//! Since PR 7 the pool is shared by every worker of the multi-worker
//! engine, so all state lives behind one internal mutex and every method
//! takes `&self`: the byte counter, the LRU order and the insert/evict
//! decision are a single critical section — there is no check-then-act
//! window where two workers can both observe "fits" and overshoot the
//! byte cap, and cross-worker preemption can park victims from any thread
//! (`SwapPool<S>` is `Send + Sync` whenever `S: Send`).
//!
//! Snapshots are pure host-side copies: they pin NO arena blocks, so with
//! refcounted prefix sharing an LRU drop (or discard) of a parked
//! snapshot can never free a physical page another live sequence still
//! shares — the victim's own claims were already released by refcount
//! when it was preempted. Asserted in `tests/prefix_cache.rs`.

use std::collections::VecDeque;
use std::sync::{Mutex, MutexGuard};

use super::backend::HostSnapshot;

/// All mutable pool state, guarded as ONE unit so byte accounting and LRU
/// order can never diverge under concurrent insert/evict.
#[derive(Debug)]
struct Inner<S> {
    used_bytes: usize,
    /// Insertion order, oldest first — the front is the next LRU victim.
    entries: VecDeque<(u64, usize, S)>,
    dropped: u64,
}

/// Byte-capped LRU store of per-request snapshots, keyed by request id.
///
/// Thread-safe: all methods take `&self` and serialize on an internal
/// mutex, so one pool instance can back every worker of the engine.
#[derive(Debug)]
pub struct SwapPool<S> {
    cap_bytes: usize,
    inner: Mutex<Inner<S>>,
}

impl<S: HostSnapshot> SwapPool<S> {
    /// A pool with `cap_bytes == 0` is disabled: every insert fails and
    /// the scheduler preempts by recompute only.
    pub fn new(cap_bytes: usize) -> Self {
        SwapPool {
            cap_bytes,
            inner: Mutex::new(Inner { used_bytes: 0, entries: VecDeque::new(), dropped: 0 }),
        }
    }

    /// Serialize on the pool state. A poisoned lock means another worker
    /// panicked mid-operation; the accounting invariant is maintained at
    /// every await-free point, so we keep serving rather than propagate.
    fn lock(&self) -> MutexGuard<'_, Inner<S>> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cap_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.lock().used_bytes
    }

    pub fn len(&self) -> usize {
        self.lock().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().entries.is_empty()
    }

    /// Snapshots LRU-dropped (or displaced by a re-insert for the same
    /// request) never restored — their victims fell back to recompute.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    pub fn contains(&self, id: u64) -> bool {
        self.lock().entries.iter().any(|(i, _, _)| *i == id)
    }

    /// Arena blocks the parked snapshot for `id` would claim on restore —
    /// the scheduler's admission estimate for a swapped victim.
    pub fn arena_blocks_of(&self, id: u64) -> Option<usize> {
        self.lock()
            .entries
            .iter()
            .find(|(i, _, _)| *i == id)
            .map(|(_, _, s)| s.arena_blocks())
    }

    /// Park a snapshot, evicting oldest entries until it fits. Returns
    /// `false` — and stores nothing — when the snapshot alone exceeds the
    /// pool cap (or the pool is disabled); the caller falls back to
    /// recompute. A snapshot already parked for the same id is replaced
    /// (counted in `dropped` only when a DIFFERENT id is evicted).
    ///
    /// The displace / capacity-test / evict / append sequence runs under
    /// ONE lock acquisition — concurrent inserts cannot interleave between
    /// the capacity check and the push and overshoot the cap.
    pub fn insert(&self, id: u64, snap: S) -> bool {
        let mut g = self.lock();
        Self::remove_locked(&mut g, id);
        let bytes = snap.host_bytes();
        if self.cap_bytes == 0 || bytes > self.cap_bytes {
            return false;
        }
        while g.used_bytes + bytes > self.cap_bytes {
            let (_, b, _) = g.entries.pop_front().expect("byte accounting broken");
            g.used_bytes -= b;
            g.dropped += 1;
        }
        g.used_bytes += bytes;
        g.entries.push_back((id, bytes, snap));
        true
    }

    /// Remove and return the snapshot for `id` (readmission restore).
    pub fn take(&self, id: u64) -> Option<S> {
        let mut g = self.lock();
        let pos = g.entries.iter().position(|(i, _, _)| *i == id)?;
        let (_, bytes, snap) = g.entries.remove(pos).expect("position just found");
        g.used_bytes -= bytes;
        Some(snap)
    }

    /// Drop the snapshot for `id` if parked (e.g. its request was
    /// rejected or cancelled). Not counted as an LRU drop; returns
    /// whether a snapshot was actually dropped.
    pub fn discard(&self, id: u64) -> bool {
        Self::remove_locked(&mut self.lock(), id)
    }

    fn remove_locked(g: &mut Inner<S>, id: u64) -> bool {
        if let Some(pos) = g.entries.iter().position(|(i, _, _)| *i == id) {
            let (_, bytes, _) = g.entries.remove(pos).expect("position just found");
            g.used_bytes -= bytes;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test snapshot with a settable footprint.
    struct Fake(usize);

    impl HostSnapshot for Fake {
        fn host_bytes(&self) -> usize {
            self.0
        }

        fn arena_blocks(&self) -> usize {
            self.0 / 100
        }
    }

    #[test]
    fn insert_take_roundtrip_accounts_bytes() {
        let p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(500)));
        assert_eq!(p.used_bytes(), 900);
        assert_eq!(p.arena_blocks_of(1), Some(4));
        assert!(p.take(1).is_some());
        assert_eq!(p.used_bytes(), 500);
        assert!(p.take(1).is_none(), "take removes");
        assert_eq!(p.dropped(), 0);
    }

    #[test]
    fn cap_evicts_oldest_first() {
        let p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(400)));
        // 400 + 400 + 600 > 1000: both elder snapshots must go
        assert!(p.insert(3, Fake(600)));
        assert_eq!(p.dropped(), 2);
        assert!(!p.contains(1) && !p.contains(2));
        assert!(p.contains(3));
        assert_eq!(p.used_bytes(), 600);
    }

    #[test]
    fn partial_eviction_keeps_newer_entries() {
        let p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(400)));
        assert!(p.insert(2, Fake(400)));
        assert!(p.insert(3, Fake(300)));
        assert_eq!(p.dropped(), 1, "only the oldest (1) needed to go");
        assert!(!p.contains(1));
        assert!(p.contains(2) && p.contains(3));
    }

    #[test]
    fn oversized_or_disabled_insert_fails_cleanly() {
        let p = SwapPool::new(100);
        assert!(!p.insert(1, Fake(101)), "snapshot bigger than the pool");
        assert_eq!(p.len(), 0);
        let off: SwapPool<Fake> = SwapPool::new(0);
        assert!(!off.insert(1, Fake(0)), "disabled pool parks nothing");
    }

    #[test]
    fn reinsert_same_id_replaces_without_drop() {
        let p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(600)));
        assert!(p.insert(1, Fake(700)), "own entry is displaced, not counted");
        assert_eq!(p.dropped(), 0);
        assert_eq!(p.used_bytes(), 700);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn discard_is_silent() {
        let p = SwapPool::new(1000);
        assert!(p.insert(1, Fake(500)));
        assert!(p.discard(1));
        assert!(!p.discard(2), "absent: no-op");
        assert!(p.is_empty());
        assert_eq!(p.dropped(), 0);
    }

    #[test]
    fn shared_pool_is_send_sync_and_cap_holds_under_races() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SwapPool<Fake>>();

        // Hammer one pool from several threads; the byte cap must hold at
        // every observation point and the final accounting must match the
        // surviving entries exactly.
        let p = std::sync::Arc::new(SwapPool::new(1000));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let p = std::sync::Arc::clone(&p);
            joins.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let id = t * 1000 + i;
                    p.insert(id, Fake(300));
                    assert!(p.used_bytes() <= 1000, "cap overshot");
                    if i % 3 == 0 {
                        p.take(id);
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let g = p.lock();
        let sum: usize = g.entries.iter().map(|(_, b, _)| *b).sum();
        assert_eq!(g.used_bytes, sum, "byte counter matches entries");
        assert!(g.used_bytes <= 1000);
    }
}
