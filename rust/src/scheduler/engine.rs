//! Multi-worker engine: shard the scheduler's round loop across N worker
//! threads to saturate the cores, without changing ANY request's output.
//!
//! Each worker thread owns a private [`Scheduler`] (no lock on the hot
//! round loop) over its shard of the request stream. What is shared is
//! exactly the state the paper's memory model says must be global:
//!
//!  - the physical [`BlockManager`] arena — ONE block pool, ONE
//!    content-hash prefix index, so a prefix published by worker A's
//!    prefill is a free refcount hit for worker B;
//!  - the host [`SwapPool`] — a victim parked by one worker restores on
//!    whichever worker readmits (or receives) it;
//!  - the admission-serial counter — `(priority, Reverse(admit_serial))`
//!    victim keys stay globally comparable, so cross-worker preemption
//!    picks the same victim a single big scheduler would.
//!
//! **Placement** is shortest-queue-first: a submitted request goes to the
//! worker with the smallest (published load + undelivered inbox) count,
//! ties to the lowest index. Priority buckets are respected per worker by
//! the scheduler itself.
//!
//! **Work stealing**: a worker that finishes a round with backlog donates
//! queue-TAIL entries of its lowest-priority bucket to workers it
//! observes idle (published load 0, empty inbox). The tail is the work
//! the donor would reach last, so no one's head-of-line progress
//! reorders. Entries carrying a step deadline never move — deadlines are
//! absolute against the owning worker's round clock. Claim/plan memos,
//! resume tokens and parked swap snapshots all stay valid across the
//! move because the arena and swap pool are shared.
//!
//! **Cross-worker preemption**: when a worker's admission trips the
//! watermark/`ArenaDry` with no eligible local victim while OTHER workers
//! hold the arena, it posts to a shared pressure flag instead of
//! rejecting (or erroring) the request. Every worker publishes its local
//! victim key each round; the worker owning the GLOBAL
//! `(priority, Reverse(admit_serial))`-min victim services the flag by
//! preempting that victim into the shared swap pool. Pressure is
//! level-triggered — a still-starved worker simply re-posts next round —
//! and preemption is lossless (restore-or-replay), so transient
//! over-preemption can never change an output.
//!
//! Per-request outputs are bit-identical regardless of worker count,
//! placement, steals or cross-worker preemptions (greedy decode is a
//! pure function of the token history; preemption/replay is lossless) —
//! pinned by the twin-run matrix in `tests/multi_worker.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::DecodeBackend;
use super::request::{Priority, Request, RequestOutput};
use super::sched::{QueueEntry, SchedConfig, Scheduler};
use super::swap::SwapPool;
use crate::api::{RequestBuilder, RequestId, SeqEvent};
use crate::kvcache::BlockManager;

/// How long an idle worker parks on its inbox before rechecking shared
/// state (pressure flag, drain deadline).
const IDLE_PARK: Duration = Duration::from_millis(2);

/// State shared by every worker and the front end. Non-generic so the
/// scheduler's [`PressureHook`] can reference it without dragging the
/// backend type into `sched.rs`.
struct EngineShared {
    /// Per-worker load (pending + running) published after every round —
    /// the placement and donation signal.
    loads: Vec<AtomicUsize>,
    /// Per-worker count of Submit/Inject messages sent but not yet
    /// received, so placement sees work the owner has not drained yet.
    inbox_depth: Vec<AtomicUsize>,
    /// Per-worker running count published after every round — the
    /// "can anyone free arena blocks for me?" signal.
    running: Vec<AtomicUsize>,
    /// Per-worker local victim key (`None` = nothing running there).
    victim_keys: Mutex<Vec<Option<(Priority, u64)>>>,
    /// Level-triggered reclaim flag: a starved worker sets it, the worker
    /// owning the global victim clears it by preempting.
    pressure: AtomicUsize,
    /// Queue entries moved to an idle worker (donation-style stealing).
    steals: AtomicU64,
    /// Victims preempted to serve ANOTHER worker's reclaim request.
    cross_preempts: AtomicU64,
}

impl EngineShared {
    fn new(workers: usize) -> EngineShared {
        EngineShared {
            loads: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            inbox_depth: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            running: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            victim_keys: Mutex::new(vec![None; workers]),
            pressure: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            cross_preempts: AtomicU64::new(0),
        }
    }

    /// Poison-tolerant lock: victim keys are plain `Copy` data, always
    /// consistent, so a panicking worker must not wedge its peers.
    fn keys(&self) -> MutexGuard<'_, Vec<Option<(Priority, u64)>>> {
        match self.victim_keys.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The scheduler's view of the shared engine state (installed via
/// `Scheduler::set_pressure_hook`): global-running visibility for the
/// admission/ArenaDry fallbacks plus the reclaim flag.
pub(crate) struct PressureHook {
    worker: usize,
    shared: Arc<EngineShared>,
}

impl PressureHook {
    /// Sequences running on OTHER workers right now (post-round
    /// snapshots — a conservative, level-triggered signal).
    pub(crate) fn others_running(&self) -> usize {
        self.shared
            .running
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != self.worker)
            .map(|(_, r)| r.load(Ordering::Relaxed))
            .sum()
    }

    /// Raise the reclaim flag (idempotent — level-triggered).
    pub(crate) fn post(&self) {
        self.shared.pressure.store(1, Ordering::Relaxed);
    }
}

/// Control messages a worker drains between rounds.
enum WorkerMsg<B: DecodeBackend> {
    Submit(Request),
    /// A queue entry donated by a loaded peer (work stealing). Boxed:
    /// entries carry the full resume state and dwarf the other variants.
    Inject(Box<QueueEntry<B::PrefillPlan>>),
    Cancel(u64, Sender<bool>),
    /// Begin draining; cancel whatever is still live at the deadline
    /// (`None` = drain fully).
    Shutdown(Option<Instant>),
}

/// Final per-worker serving counters, returned by
/// [`MultiEngine::shutdown`] (the scheduler's aggregate metrics, split by
/// worker, plus round/utilization accounting).
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    /// Scheduling rounds this worker ran.
    pub rounds: u64,
    /// Rounds that decoded at least one sequence — `busy_rounds / rounds`
    /// is the per-worker utilization column of `fig3_throughput`.
    pub busy_rounds: u64,
    pub decoded_tokens: u64,
    pub prompt_tokens: u64,
    pub preemptions: u64,
    pub swap_outs: u64,
    pub swap_restores: u64,
    pub prefix_hit_blocks: u64,
    pub cow_copies: u64,
    /// Chunked-prefill advances this worker ran (0 unless
    /// `SchedConfig::prefill_chunk` is set).
    pub chunk_prefills: u64,
    pub fault_retries: u64,
    pub quarantined: u64,
    pub cancelled: u64,
    /// `--policy auto` resolutions this worker made, by chosen policy
    /// (empty unless the autotuner ran). Sum across workers with
    /// [`super::autotune::AutotuneStats::merge`] for the run total.
    pub autotune: super::autotune::AutotuneStats,
}

impl WorkerStats {
    /// Fraction of this worker's rounds that decoded work.
    pub fn utilization(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.busy_rounds as f64 / self.rounds as f64
        }
    }
}

/// What [`MultiEngine::shutdown`] returns: per-worker stats, the engine
/// totals, and any terminal outputs that raced the teardown.
#[derive(Debug)]
pub struct EngineReport {
    pub workers: Vec<WorkerStats>,
    /// Queue entries moved to an idle worker.
    pub steals: u64,
    /// Victims preempted for another worker's reclaim request.
    pub cross_preempts: u64,
    /// Finished outputs drained from the event channel after the join.
    pub leftover: Vec<RequestOutput>,
}

/// One worker thread: a private scheduler plus the glue to its peers.
struct Worker<B: DecodeBackend> {
    idx: usize,
    sched: Scheduler<B>,
    inbox: Receiver<WorkerMsg<B>>,
    /// Senders to every peer inbox (`None` at our own index) — the
    /// donation path.
    peers: Vec<Option<Sender<WorkerMsg<B>>>>,
    events: Sender<(u64, SeqEvent)>,
    shared: Arc<EngineShared>,
    draining: bool,
    deadline: Option<Instant>,
    rounds: u64,
    busy_rounds: u64,
}

impl<B: DecodeBackend> Worker<B> {
    fn run(mut self) -> (WorkerStats, B) {
        loop {
            // drain control messages accumulated during the last round
            loop {
                match self.inbox.try_recv() {
                    Ok(msg) => self.handle(msg),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            if self.draining {
                if self.sched.is_idle() {
                    break;
                }
                if self.deadline.is_some_and(|d| Instant::now() >= d) {
                    // grace expired: cancel everything still live so the
                    // arena and swap pool drain before the join
                    for id in self.sched.live_ids() {
                        self.sched.cancel(id);
                    }
                    break;
                }
            }
            if self.sched.is_idle() {
                self.publish();
                self.flush_events();
                // an idle worker's leased slot stock is pure inventory:
                // hand it back so busy peers get it without a drain sweep
                self.sched.flush_slot_cache();
                if self.shared.pressure.load(Ordering::Relaxed) > 0 {
                    // nothing running here, but help clear a stale flag
                    // (all victim keys None => nothing to reclaim)
                    self.service_pressure();
                }
                match self.inbox.recv_timeout(IDLE_PARK) {
                    Ok(msg) => self.handle(msg),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => self.draining = true,
                }
                continue;
            }
            self.service_pressure();
            if let Err(e) = self.sched.step() {
                log::warn!("worker {}: round failed: {e:#}", self.idx);
            }
            self.rounds += 1;
            if self.sched.running() > 0 {
                self.busy_rounds += 1;
            }
            self.flush_events();
            self.publish();
            self.donate();
        }
        self.flush_events();
        // peers must not keep seeing ghost load on a dead worker
        self.shared.loads[self.idx].store(0, Ordering::Relaxed);
        self.shared.running[self.idx].store(0, Ordering::Relaxed);
        self.shared.keys()[self.idx] = None;
        let stats = WorkerStats {
            worker: self.idx,
            rounds: self.rounds,
            busy_rounds: self.busy_rounds,
            decoded_tokens: self.sched.total_generated,
            prompt_tokens: self.sched.total_prompt_tokens,
            preemptions: self.sched.preemptions,
            swap_outs: self.sched.swap_outs,
            swap_restores: self.sched.swap_restores,
            prefix_hit_blocks: self.sched.prefix_hit_blocks,
            cow_copies: self.sched.cow_copies,
            chunk_prefills: self.sched.chunk_prefills,
            fault_retries: self.sched.fault_retries,
            quarantined: self.sched.quarantined,
            cancelled: self.sched.cancelled(),
            autotune: self.sched.autotune.clone(),
        };
        // hand the backend back so interior counters (sim call tallies,
        // fault counts) outlive the thread
        (stats, self.sched.into_backend())
    }

    fn handle(&mut self, msg: WorkerMsg<B>) {
        match msg {
            WorkerMsg::Submit(req) => {
                self.shared.inbox_depth[self.idx].fetch_sub(1, Ordering::Relaxed);
                self.sched.submit(req);
            }
            WorkerMsg::Inject(entry) => {
                self.shared.inbox_depth[self.idx].fetch_sub(1, Ordering::Relaxed);
                self.sched.inject(*entry);
            }
            WorkerMsg::Cancel(id, reply) => {
                let _ = reply.send(self.sched.cancel(id));
            }
            WorkerMsg::Shutdown(deadline) => {
                self.draining = true;
                self.deadline = deadline;
            }
        }
    }

    fn flush_events(&mut self) {
        for ev in self.sched.take_events() {
            // the front end hanging up mid-flight only happens on
            // teardown; remaining events have no consumer
            let _ = self.events.send(ev);
        }
    }

    fn publish(&self) {
        self.shared.loads[self.idx]
            .store(self.sched.pending() + self.sched.running(), Ordering::Relaxed);
        self.shared.running[self.idx].store(self.sched.running(), Ordering::Relaxed);
        self.shared.keys()[self.idx] = self.sched.min_victim_key();
    }

    /// Serve the shared reclaim flag: if OUR local victim is the global
    /// `(priority, Reverse(admit_serial))` minimum, preempt it into the
    /// shared swap pool and clear the flag. A stale flag (nothing running
    /// anywhere) is cleared outright — the poster re-posts while starved.
    fn service_pressure(&mut self) {
        if self.shared.pressure.load(Ordering::Relaxed) == 0 {
            return;
        }
        let owner = {
            let mut keys = self.shared.keys();
            keys[self.idx] = self.sched.min_victim_key();
            keys.iter()
                .enumerate()
                .filter_map(|(i, k)| k.map(|key| (i, key)))
                .min_by_key(|&(_, (p, s))| (p, std::cmp::Reverse(s)))
                .map(|(i, _)| i)
        };
        match owner {
            None => self.shared.pressure.store(0, Ordering::Relaxed),
            Some(o) if o == self.idx => {
                if self.sched.preempt_min() {
                    self.shared.cross_preempts.fetch_add(1, Ordering::Relaxed);
                    self.shared.pressure.store(0, Ordering::Relaxed);
                    self.shared.keys()[self.idx] = self.sched.min_victim_key();
                }
            }
            Some(_) => {} // the owning worker will service it
        }
    }

    /// Donate queue-tail entries to idle peers. Runs after the round's
    /// event flush, so a preempted entry's `Preempted` event is already
    /// in the channel before the thief can emit its `Resumed` —
    /// per-request event order survives the move.
    fn donate(&mut self) {
        if self.draining {
            // peers may exit any moment; keep our shard local
            return;
        }
        // keep our own next unit of work: donating the only queued entry
        // of an otherwise-idle worker just moves the idleness around
        while self.sched.pending() >= 1
            && (self.sched.running() >= 1 || self.sched.pending() >= 2)
        {
            let Some(peer) = (0..self.peers.len()).find(|&i| {
                i != self.idx
                    && self.shared.loads[i].load(Ordering::Relaxed) == 0
                    && self.shared.inbox_depth[i].load(Ordering::Relaxed) == 0
            }) else {
                break;
            };
            let Some(entry) = self.sched.steal_tail() else {
                break; // every queued entry is deadline-pinned
            };
            self.shared.inbox_depth[peer].fetch_add(1, Ordering::Relaxed);
            let tx = self.peers[peer].as_ref().expect("peer sender");
            match tx.send(WorkerMsg::Inject(Box::new(entry))) {
                Ok(()) => {
                    self.shared.steals.fetch_add(1, Ordering::Relaxed);
                    self.shared.loads[self.idx]
                        .store(self.sched.pending() + self.sched.running(), Ordering::Relaxed);
                }
                Err(mpsc::SendError(msg)) => {
                    // peer already exited: take the entry back
                    self.shared.inbox_depth[peer].fetch_sub(1, Ordering::Relaxed);
                    if let WorkerMsg::Inject(entry) = msg {
                        self.sched.inject(*entry);
                    }
                    break;
                }
            }
        }
    }
}

/// The multi-worker serving engine (see the module docs for the sharing,
/// placement, stealing and cross-worker preemption rules).
///
/// `workers == 1` degenerates to the classic single scheduler on one
/// thread: every multi-worker fallback is gated on other workers actually
/// running work, so the behavior — and every output — is identical.
pub struct MultiEngine<B: DecodeBackend> {
    cfg: SchedConfig,
    arena: BlockManager,
    swap: Arc<SwapPool<B::Snapshot>>,
    shared: Arc<EngineShared>,
    inboxes: Vec<Sender<WorkerMsg<B>>>,
    handles: Vec<JoinHandle<(WorkerStats, B)>>,
    event_rx: Receiver<(u64, SeqEvent)>,
    /// Requests submitted and not yet seen terminal (finished or
    /// cancelled) — `run_to_completion`'s stop condition.
    inflight: usize,
    /// Globally monotonic request ids handed out by [`Self::submit_builder`]
    /// (same convention as `api::Session`: first id is 1).
    next_id: u64,
}

impl<B> MultiEngine<B>
where
    B: DecodeBackend + Send + 'static,
    B::Seq: Send + 'static,
    B::Snapshot: Send + 'static,
    B::PrefillPlan: Send + 'static,
{
    /// Spawn `cfg.workers` worker threads, each over its own backend
    /// instance from `mk_backend(worker_idx)` (per-worker backends keep
    /// interior counters — sim call tallies, fault lanes — per-worker-
    /// stable), all over ONE arena, ONE swap pool and ONE admission
    /// serial source.
    pub fn new(cfg: SchedConfig, mut mk_backend: impl FnMut(usize) -> B) -> Self {
        let n = cfg.workers.max(1);
        let arena = BlockManager::new(cfg.max_live_blocks);
        arena.set_watermarks(cfg.watermark_low, cfg.watermark_high);
        let swap = Arc::new(SwapPool::new(cfg.swap_bytes));
        let serial = Arc::new(AtomicU64::new(0));
        let shared = Arc::new(EngineShared::new(n));
        let (event_tx, event_rx) = mpsc::channel();
        let mut inboxes = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            inboxes.push(tx);
            rxs.push(rx);
        }
        let mut handles = Vec::with_capacity(n);
        for (i, rx) in rxs.into_iter().enumerate() {
            let mut sched = Scheduler::with_shared(
                mk_backend(i),
                cfg.clone(),
                arena.clone(),
                swap.clone(),
                serial.clone(),
            );
            sched.set_pressure_hook(PressureHook { worker: i, shared: shared.clone() });
            // streaming costs nothing for requests that did not opt in
            // (`Request::stream_events` gates per-request), and the
            // serve layer needs the token events
            sched.set_event_streaming(true);
            let peers: Vec<Option<Sender<WorkerMsg<B>>>> = inboxes
                .iter()
                .enumerate()
                .map(|(j, tx)| if j == i { None } else { Some(tx.clone()) })
                .collect();
            let worker = Worker {
                idx: i,
                sched,
                inbox: rx,
                peers,
                events: event_tx.clone(),
                shared: shared.clone(),
                draining: false,
                deadline: None,
                rounds: 0,
                busy_rounds: 0,
            };
            let handle = std::thread::Builder::new()
                .name(format!("sched-worker-{i}"))
                .spawn(move || worker.run())
                .expect("spawn scheduler worker");
            handles.push(handle);
        }
        MultiEngine {
            cfg,
            arena,
            swap,
            shared,
            inboxes,
            handles,
            event_rx,
            inflight: 0,
            next_id: 0,
        }
    }

    /// Worker threads serving this engine.
    pub fn workers(&self) -> usize {
        self.inboxes.len()
    }

    /// The shared physical block arena.
    pub fn arena(&self) -> &BlockManager {
        &self.arena
    }

    /// The shared host swap pool.
    pub fn swap_pool(&self) -> &SwapPool<B::Snapshot> {
        &self.swap
    }

    /// Queue entries moved to an idle worker so far.
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Victims preempted for another worker's reclaim request so far.
    pub fn cross_preempts(&self) -> u64 {
        self.shared.cross_preempts.load(Ordering::Relaxed)
    }

    /// Place a request on the shortest queue (published load plus
    /// undelivered inbox; ties to the lowest worker index). Ids are the
    /// caller's, exactly like `Scheduler::submit` — the session/serve
    /// layers keep them globally monotonic.
    pub fn submit(&mut self, req: Request) {
        let w = (0..self.inboxes.len())
            .min_by_key(|&i| {
                (
                    self.shared.loads[i].load(Ordering::Relaxed)
                        + self.shared.inbox_depth[i].load(Ordering::Relaxed),
                    i,
                )
            })
            .expect("engine has at least one worker");
        self.shared.inbox_depth[w].fetch_add(1, Ordering::Relaxed);
        match self.inboxes[w].send(WorkerMsg::Submit(req)) {
            Ok(()) => self.inflight += 1,
            Err(mpsc::SendError(_)) => {
                self.shared.inbox_depth[w].fetch_sub(1, Ordering::Relaxed);
                log::warn!("submit after engine shutdown — dropped");
            }
        }
    }

    /// Submit via the public [`RequestBuilder`] surface: stamps a fresh
    /// globally monotonic [`RequestId`] (same convention as
    /// `api::Session` — ids start at 1 and are never reused), validates
    /// like the session does (empty prompt / unknown policy fail fast,
    /// nothing queued on error), then places the request on the shortest
    /// queue.
    pub fn submit_builder(&mut self, builder: RequestBuilder) -> anyhow::Result<RequestId> {
        anyhow::ensure!(builder.prompt_len() > 0, "empty prompt");
        self.next_id += 1;
        let id = RequestId(self.next_id);
        let req = builder.build(id, &self.cfg);
        // surface bad policy names at submit ("auto" is valid here: the
        // owning worker's scheduler resolves it at its own submit time)
        crate::eviction::validate_request_policy(&req.policy)?;
        self.submit(req);
        Ok(id)
    }

    /// Requests submitted and not yet terminal (finished or cancelled).
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Cancel a request wherever it lives. Stealing moves entries between
    /// workers behind the front end's back, so this fans out to every
    /// worker and short-circuits on the first hit. Synchronous, like
    /// `Scheduler::cancel`.
    pub fn cancel(&mut self, id: u64) -> bool {
        for tx in &self.inboxes {
            let (reply_tx, reply_rx) = mpsc::channel();
            if tx.send(WorkerMsg::Cancel(id, reply_tx)).is_err() {
                continue;
            }
            if reply_rx
                .recv_timeout(Duration::from_secs(5))
                .unwrap_or(false)
            {
                self.inflight = self.inflight.saturating_sub(1);
                return true;
            }
        }
        false
    }

    /// Next lifecycle event from any worker, or `None` on timeout.
    /// Per-request event order is preserved (each request's events come
    /// from its current owner, and ownership only moves while queued).
    pub fn next_event(&mut self, timeout: Duration) -> Option<(u64, SeqEvent)> {
        match self.event_rx.recv_timeout(timeout) {
            Ok((id, ev)) => {
                if matches!(ev, SeqEvent::Finished(_)) {
                    self.inflight = self.inflight.saturating_sub(1);
                }
                Some((id, ev))
            }
            Err(_) => None,
        }
    }

    /// Block until every request submitted so far reached a terminal
    /// event, returning the outputs sorted by id (streaming events are
    /// discarded — the `take_finished` compat semantics). Workers stay up
    /// for further submissions.
    pub fn run_to_completion(&mut self) -> Vec<RequestOutput> {
        let mut outs = Vec::new();
        let mut last_progress = Instant::now();
        while self.inflight > 0 {
            match self.next_event(Duration::from_millis(100)) {
                Some((_, SeqEvent::Finished(out))) => {
                    outs.push(out);
                    last_progress = Instant::now();
                }
                Some(_) => last_progress = Instant::now(),
                None => {
                    if last_progress.elapsed() > Duration::from_secs(30) {
                        log::warn!(
                            "engine stalled with {} request(s) unaccounted for",
                            self.inflight
                        );
                        break;
                    }
                }
            }
        }
        outs.sort_by_key(|o| o.id);
        outs
    }

    /// Drain every worker to ONE wall-clock deadline (live requests past
    /// it are cancelled), join the threads, and return the per-worker
    /// stats plus engine totals, along with each worker's backend (sorted
    /// by worker index, like the stats) so callers can read interior
    /// counters — fault tallies, sim claim/scan counts.
    pub fn shutdown(mut self, grace: Duration) -> (EngineReport, Vec<B>) {
        let deadline = Instant::now() + grace;
        for tx in &self.inboxes {
            let _ = tx.send(WorkerMsg::Shutdown(Some(deadline)));
        }
        self.inboxes.clear();
        let mut joined = Vec::new();
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(pair) => joined.push(pair),
                Err(_) => log::warn!("scheduler worker panicked"),
            }
        }
        joined.sort_by_key(|(w, _)| w.worker);
        let (workers, backends): (Vec<_>, Vec<_>) = joined.into_iter().unzip();
        let mut leftover = Vec::new();
        while let Ok((_, ev)) = self.event_rx.try_recv() {
            if let SeqEvent::Finished(out) = ev {
                leftover.push(out);
            }
        }
        let report = EngineReport {
            workers,
            steals: self.shared.steals.load(Ordering::Relaxed),
            cross_preempts: self.shared.cross_preempts.load(Ordering::Relaxed),
            leftover,
        };
        (report, backends)
    }
}

impl MultiEngine<crate::runtime::SimBackend> {
    /// Multi-worker engine over per-worker sim backends.
    pub fn new_sim(cfg: SchedConfig) -> Self {
        let page = cfg.page_size;
        Self::new(cfg, move |_| crate::runtime::SimBackend::new(page))
    }
}

impl MultiEngine<crate::runtime::FaultyBackend<crate::runtime::SimBackend>> {
    /// Multi-worker engine over per-worker fault-injecting sim backends.
    /// Every worker gets its own clone of the ONE plan, so fault lanes
    /// number each worker's prefills from 1 — per-worker-stable no matter
    /// how placement or stealing distributes the requests.
    pub fn new_sim_faulty(cfg: SchedConfig, plan: crate::runtime::FaultPlan) -> Self {
        let page = cfg.page_size;
        Self::new(cfg, move |_| {
            crate::runtime::FaultyBackend::new(
                crate::runtime::SimBackend::new(page),
                plan.clone(),
            )
        })
    }
}
