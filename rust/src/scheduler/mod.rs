//! Serving coordinator: request lifecycle + continuous batching.
//!
//! The scheduler owns the `ModelRunner` and interleaves many in-flight
//! sequences vLLM-style: at most one prefill per scheduling round (prefill
//! is the long pole), then one decode step for every running sequence.
//! Eviction policy + cache budget are per-request, so a single server can
//! serve mixed policies (that is how the comparison benches run).
//!
//! On this testbed PJRT executes on a single CPU core, so "batching" is
//! round-robin interleave rather than a batched kernel launch; admission,
//! preemption and block accounting are the same logic a parallel backend
//! would use (DESIGN.md §4, substitution table).

pub mod request;
pub mod sched;

pub use request::{FinishReason, Request, RequestOutput, RequestState};
pub use sched::{SchedConfig, Scheduler, StepReport};
