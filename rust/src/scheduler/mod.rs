//! Serving coordinator: request lifecycle + continuous batching.
//!
//! The scheduler interleaves many in-flight sequences vLLM-style: each
//! round admits work while the shared arena sits below its LOW watermark,
//! preempts the youngest sequence when usage crosses the HIGH watermark
//! (or an allocation hard-fails), reserves this round's blocks, then
//! issues ONE batched decode call for the whole running set. Preemption
//! victims are swapped to a bounded host [`swap::SwapPool`] when the
//! backend can snapshot them — readmission restores instead of
//! recomputing — and fall back to recompute-and-replay otherwise.
//! Eviction policy + cache budget are per-request, so a single server can
//! serve mixed policies (that is how the comparison benches run), and
//! each request carries a [`Priority`]: admission prefers the
//! highest-priority queued work and preemption victimizes the
//! lowest-priority running work (youngest within a class).
//!
//! Lifecycle transitions stream out as `api::SeqEvent`s
//! ([`Scheduler::take_events`]); `take_finished` remains as a compat
//! shim over the same stream. [`Scheduler::cancel`] synchronously frees
//! a request wherever it lives. The session-based public surface over
//! all of this is [`crate::api`].
//!
//! The scheduler is generic over [`backend::DecodeBackend`], so the whole
//! lifecycle — admission gating on the shared `BlockManager` arena,
//! batched decode rounds, preemption under memory pressure, retirement —
//! is identical between the always-built deterministic sim backend and the
//! PJRT runtime (`--features xla`), and is exercised by plain
//! `cargo test`.

//! The multi-worker [`engine`] shards this round loop across threads:
//! each worker owns a private `Scheduler` over its shard of the request
//! stream while the arena, prefix index, swap pool and admission-serial
//! source are shared, so placement/stealing/cross-worker preemption never
//! change any request's output.

pub mod autotune;
pub mod backend;
pub mod engine;
pub mod request;
pub mod sched;
pub mod swap;

pub use autotune::{AutotuneStats, PressureSnapshot};
pub use backend::{
    BackendError, ClaimMemo, DecodeBackend, HostSnapshot, Prefilled, PrefillStep, Restored,
};
pub use engine::{EngineReport, MultiEngine, WorkerStats};
pub use request::{FinishReason, Priority, Request, RequestOutput, RequestState};
pub use sched::{default_workers, SchedConfig, Scheduler, StepReport};
pub use swap::SwapPool;
