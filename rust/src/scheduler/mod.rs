//! Serving coordinator: request lifecycle + continuous batching.
//!
//! The scheduler owns the `ModelRunner` and interleaves many in-flight
//! sequences vLLM-style: each round admits prefills until the concurrency
//! or global-block budget is exhausted, then runs one decode step for
//! every running sequence. Eviction policy + cache budget are per-request,
//! so a single server can serve mixed policies (that is how the comparison
//! benches run).
//!
//! On this testbed PJRT executes on a single CPU core, so "batching" is
//! round-robin interleave rather than a batched kernel launch; admission,
//! preemption and block accounting are the same logic a parallel backend
//! would use (DESIGN.md §4, substitution table).
//!
//! The scheduler drives the PJRT runtime, so `sched` is gated behind the
//! `xla` feature; the request/response types are always available (the
//! wire protocol depends on them).

pub mod request;
#[cfg(feature = "xla")]
pub mod sched;

pub use request::{FinishReason, Request, RequestOutput, RequestState};
#[cfg(feature = "xla")]
pub use sched::{SchedConfig, Scheduler, StepReport};
