//! Serving coordinator: request lifecycle + continuous batching.
//!
//! The scheduler interleaves many in-flight sequences vLLM-style: each
//! round admits prefills until the concurrency or shared-arena capacity is
//! exhausted, reserves this round's blocks (preempting the youngest
//! sequence when the arena runs dry), then issues ONE batched decode call
//! for the whole running set. Eviction policy + cache budget are
//! per-request, so a single server can serve mixed policies (that is how
//! the comparison benches run).
//!
//! The scheduler is generic over [`backend::DecodeBackend`], so the whole
//! lifecycle — admission gating on the shared `BlockManager` arena,
//! batched decode rounds, preemption under memory pressure, retirement —
//! is identical between the always-built deterministic sim backend and the
//! PJRT runtime (`--features xla`), and is exercised by plain
//! `cargo test`.

pub mod backend;
pub mod request;
pub mod sched;

pub use backend::{DecodeBackend, Prefilled};
pub use request::{FinishReason, Request, RequestOutput, RequestState};
pub use sched::{SchedConfig, Scheduler, StepReport};
