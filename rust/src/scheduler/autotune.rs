//! The per-request policy autotuner's scheduler half (`--policy auto`).
//!
//! A request submitted with the [`AUTO_POLICY`] sentinel gets its eviction
//! policy and cache budget resolved AT SUBMIT TIME from two inputs:
//!
//!   * the request itself — prompt length ([`auto::classify_prompt`]) and
//!     how many leading prompt blocks the prefix index would serve by
//!     reference ([`crate::scheduler::DecodeBackend::shared_prefix_depth`]);
//!   * a [`PressureSnapshot`] of the shared arena, read through the PR 9
//!     lock-free counters (`used`/watermark loads, no arena lock).
//!
//! The decision itself — [`choose`] — is a pure function of those inputs
//! delegating to the [`auto::pick_policy`] table, so the same (request,
//! snapshot) pair resolves identically at any worker count. Resolution
//! rides the PR 5 per-request override machinery: the chosen policy and
//! budget are written into the [`crate::scheduler::Request`] before
//! admission ever sees it, and surface back to callers in
//! `RequestOutput::policy`. The sim backend's token streams are
//! policy-invariant besides, so `--policy auto` digests stay bit-identical
//! at workers 1 vs 4 (the schedule-smoke CI leg compares them).

use std::collections::BTreeMap;

use crate::eviction::auto::{self, PressureBand};
use crate::kvcache::BlockManager;

pub use crate::eviction::auto::AUTO_POLICY;

/// A point-in-time read of arena occupancy — everything [`choose`] is
/// allowed to know about global state, captured once per resolution so
/// the decision is a pure function of an explicit value rather than of
/// racy re-reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PressureSnapshot {
    pub used: usize,
    pub capacity: usize,
    pub above_high: bool,
    pub below_low: bool,
}

impl PressureSnapshot {
    /// Read the arena's lock-free occupancy counters (relaxed loads — the
    /// same reads the round loop's admission gate uses).
    pub fn read(arena: &BlockManager) -> PressureSnapshot {
        PressureSnapshot {
            used: arena.used(),
            capacity: arena.capacity(),
            above_high: arena.above_high_watermark(),
            below_low: arena.below_low_watermark(0),
        }
    }

    /// An empty-arena snapshot (tests, and backends with no arena).
    pub fn idle(capacity: usize) -> PressureSnapshot {
        PressureSnapshot { used: 0, capacity, above_high: false, below_low: true }
    }

    /// Collapse the snapshot to the decision table's pressure band.
    pub fn band(&self) -> PressureBand {
        if self.above_high {
            PressureBand::High
        } else if self.below_low {
            PressureBand::Low
        } else {
            PressureBand::Normal
        }
    }
}

/// One resolved `--policy auto` decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Choice {
    /// A concrete `eviction::registry` entry name.
    pub policy: &'static str,
    /// The (possibly pressure-shrunk) cache budget in tokens.
    pub budget: usize,
}

/// Resolve policy + budget for one request. Pure: same inputs, same
/// choice, whatever thread or worker count asks. Under a High-pressure
/// band the budget is halved (floor: two pages — one page of content plus
/// the write block every decode round reserves), trading retention for
/// admission headroom exactly when the arena is preemption-bound.
pub fn choose(
    prompt_len: usize,
    prefix_hit_blocks: usize,
    base_budget: usize,
    page_size: usize,
    snap: &PressureSnapshot,
) -> Choice {
    let band = snap.band();
    let policy = auto::pick_policy(auto::classify_prompt(prompt_len), band, prefix_hit_blocks);
    let floor = 2 * page_size.max(1);
    let budget = if band == PressureBand::High {
        (base_budget / 2).max(floor.min(base_budget))
    } else {
        base_budget
    };
    Choice { policy, budget }
}

/// Pick counters (policy name -> resolutions), kept in a `BTreeMap` so
/// iteration — and therefore every printed summary — is deterministically
/// ordered. One lives per scheduler; the multi-worker engine sums its
/// workers' counters into the run report.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AutotuneStats {
    picks: BTreeMap<&'static str, u64>,
}

impl AutotuneStats {
    pub fn record(&mut self, policy: &'static str) {
        *self.picks.entry(policy).or_insert(0) += 1;
    }

    /// Total `--policy auto` resolutions.
    pub fn total(&self) -> u64 {
        self.picks.values().sum()
    }

    pub fn counts(&self) -> &BTreeMap<&'static str, u64> {
        &self.picks
    }

    /// Fold another worker's counters into this one.
    pub fn merge(&mut self, other: &AutotuneStats) {
        for (name, n) in &other.picks {
            *self.picks.entry(name).or_insert(0) += n;
        }
    }

    /// `"paged=3 self_attn=2"` — stable order, empty string when unused.
    pub fn summary(&self) -> String {
        let mut parts = Vec::with_capacity(self.picks.len());
        for (name, n) in &self.picks {
            parts.push(format!("{name}={n}"));
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::registry;

    #[test]
    fn banding_tracks_the_watermarks() {
        assert_eq!(PressureSnapshot::idle(64).band(), PressureBand::Low);
        let normal =
            PressureSnapshot { used: 40, capacity: 64, above_high: false, below_low: false };
        assert_eq!(normal.band(), PressureBand::Normal);
        let high = PressureSnapshot { used: 60, capacity: 64, above_high: true, below_low: false };
        assert_eq!(high.band(), PressureBand::High);
    }

    #[test]
    fn snapshot_read_matches_the_arena_counters() {
        let arena = BlockManager::new(64);
        let snap = PressureSnapshot::read(&arena);
        assert_eq!(snap, PressureSnapshot::idle(64));
        arena.set_watermarks(0.5, 0.75); // low = 32, high = 48
        let seq = arena.register();
        let blocks = arena.alloc_many(seq, 60).expect("arena has room");
        let snap = PressureSnapshot::read(&arena);
        assert_eq!((snap.used, snap.capacity), (60, 64));
        assert_eq!(snap.band(), PressureBand::High);
        arena.release_many(seq, &blocks);
        arena.unregister(seq);
        assert_eq!(PressureSnapshot::read(&arena).band(), PressureBand::Low);
    }

    #[test]
    fn choose_is_pure_and_lands_in_the_registry() {
        for (len, hits, used) in [(32usize, 0usize, 0usize), (32, 2, 60), (512, 0, 40), (512, 0, 60)]
        {
            let snap = PressureSnapshot {
                used,
                capacity: 64,
                above_high: used >= 56,
                below_low: used < 32,
            };
            let a = choose(len, hits, 256, 4, &snap);
            let b = choose(len, hits, 256, 4, &snap);
            assert_eq!(a, b, "pure function of its arguments");
            assert!(registry::lookup(a.policy).is_some(), "{} not registered", a.policy);
        }
    }

    #[test]
    fn high_pressure_halves_the_budget_with_a_two_page_floor() {
        let high = PressureSnapshot { used: 60, capacity: 64, above_high: true, below_low: false };
        let low = PressureSnapshot::idle(64);
        assert_eq!(choose(512, 0, 256, 4, &low).budget, 256);
        assert_eq!(choose(512, 0, 256, 4, &high).budget, 128);
        // floor: never below two pages...
        assert_eq!(choose(512, 0, 12, 4, &high).budget, 8);
        // ...but also never ABOVE what the caller asked for
        assert_eq!(choose(512, 0, 6, 4, &high).budget, 6);
    }

    #[test]
    fn stats_merge_and_summarize_deterministically() {
        let mut a = AutotuneStats::default();
        assert_eq!(a.summary(), "");
        a.record("paged");
        a.record("self_attn");
        a.record("paged");
        let mut b = AutotuneStats::default();
        b.record("streaming");
        b.record("paged");
        a.merge(&b);
        assert_eq!(a.total(), 5);
        assert_eq!(a.summary(), "paged=3 self_attn=1 streaming=1");
    }
}
