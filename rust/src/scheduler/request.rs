//! Request descriptors and lifecycle state.

use anyhow::Result;

/// Scheduling class of a request. Preemption victims are chosen from the
/// LOWEST priority class first (youngest within a class), and admission
/// prefers the highest-priority queued request, so `High` work both jumps
/// the queue and survives memory pressure at the expense of `Low` work.
///
/// The derived order is `Low < Normal < High`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Parse a wire/CLI priority name.
    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            _ => anyhow::bail!("unknown priority {s:?} (want low|normal|high)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// KV-cache budget in tokens for this request.
    pub budget: usize,
    /// Eviction policy name (see `eviction::make_policy`).
    pub policy: String,
    /// Stop generation when this token is produced (None = length only).
    /// Kept for wire/API compatibility; `stop_tokens` is the general form.
    pub eos_token: Option<u32>,
    /// Stop-token SET: generation stops when ANY of these is produced
    /// (in addition to `eos_token`, if set).
    pub stop_tokens: Vec<u32>,
    /// Scheduling class (admission order + preemption victim selection).
    pub priority: Priority,
    /// Deadline in scheduler steps after submission: once this many rounds
    /// have started, the request is finished with whatever it has produced
    /// ([`FinishReason::Deadline`]) — queued, swapped-out or mid-decode.
    pub deadline_steps: Option<u64>,
    /// Emit per-token/lifecycle streaming events for this request (the
    /// terminal `Finished` is always emitted)? One-shot consumers turn
    /// this off so nobody pays for events that would be discarded. Only
    /// effective when the scheduler's event streaming is enabled at all.
    pub stream_events: bool,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            budget: 1024,
            policy: "paged".to_string(),
            eos_token: None,
            stop_tokens: Vec::new(),
            priority: Priority::Normal,
            deadline_steps: None,
            stream_events: true,
        }
    }

    /// True when producing `tok` must stop generation (any stop token or
    /// the legacy `eos_token`).
    pub fn is_stop(&self, tok: u32) -> bool {
        self.eos_token == Some(tok) || self.stop_tokens.contains(&tok)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    Error,
    /// The request's step deadline expired before it finished; its
    /// `tokens` hold whatever had been produced by then.
    Deadline,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Running,
    Finished(FinishReason),
}

/// Completed request + serving metrics.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// The eviction policy this request actually ran under. For
    /// `--policy auto` submissions this is the autotuner's RESOLVED
    /// choice (a concrete `eviction::registry` name, never `"auto"`) —
    /// the wire surfaces it so callers can see what the tuner did.
    pub policy: String,
    pub finish: FinishReason,
    /// time from admission (enqueue) to first generated token
    pub ttft_s: f64,
    /// mean time per output token AFTER the first
    pub tpot_s: f64,
    pub prompt_len: usize,
    pub live_cache_tokens: usize,
    /// Times this request was preempted (blocks freed under memory
    /// pressure) before completing — both readmission paths.
    pub preemptions: u32,
    /// Times this request was readmitted by restoring a swap-to-host
    /// snapshot instead of recomputing (`swaps <= preemptions`).
    pub swaps: u32,
    /// Times this request was suspended and readmitted to recover a
    /// TRANSIENT decode error (not counted in `preemptions`).
    pub retries: u32,
    pub cache_stats: crate::kvcache::CacheStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_orders_low_normal_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn stop_set_and_legacy_eos_both_stop() {
        let mut r = Request::new(1, vec![1, 2], 8);
        assert!(!r.is_stop(7));
        r.eos_token = Some(7);
        r.stop_tokens = vec![9, 11];
        assert!(r.is_stop(7) && r.is_stop(9) && r.is_stop(11));
        assert!(!r.is_stop(8));
    }
}
