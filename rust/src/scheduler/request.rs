//! Request descriptors and lifecycle state.

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// KV-cache budget in tokens for this request.
    pub budget: usize,
    /// Eviction policy name (see `eviction::make_policy`).
    pub policy: String,
    /// Stop generation when this token is produced (None = length only).
    pub eos_token: Option<u32>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            budget: 1024,
            policy: "paged".to_string(),
            eos_token: None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    Error,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Running,
    Finished(FinishReason),
}

/// Completed request + serving metrics.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// time from admission (enqueue) to first generated token
    pub ttft_s: f64,
    /// mean time per output token AFTER the first
    pub tpot_s: f64,
    pub prompt_len: usize,
    pub live_cache_tokens: usize,
    /// Times this request was preempted (blocks freed under memory
    /// pressure) before completing — both readmission paths.
    pub preemptions: u32,
    /// Times this request was readmitted by restoring a swap-to-host
    /// snapshot instead of recomputing (`swaps <= preemptions`).
    pub swaps: u32,
    pub cache_stats: crate::kvcache::CacheStats,
}
