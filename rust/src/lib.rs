//! PagedEviction: structured block-wise KV cache pruning for efficient LLM
//! inference — a Rust + JAX + Pallas reproduction of Chitty-Venkata & Ye et
//! al. (2025).
//!
//! Layer 3 (this crate) is the serving coordinator: request routing,
//! continuous batching, paged KV-cache management and the block-wise
//! eviction policies that are the paper's contribution. Layer 2 (JAX) and
//! Layer 1 (Pallas) live under `python/compile/` and are AOT-lowered to HLO
//! text artifacts which `runtime` loads through the PJRT C API.

pub mod api;
pub mod eviction;
pub mod kvcache;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workload;
