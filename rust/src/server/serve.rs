//! The engine loop + TCP frontend.
//!
//! The engine loop owns an [`api::Session`] and is generic over the
//! decode backend, so the IDENTICAL loop serves the always-built sim
//! backend ([`spawn_sim_engine`], tier-1 tested over real TCP in
//! `tests/serve_v2.rs`) and the PJRT runtime ([`spawn_engine`],
//! `--features xla`). PJRT handles are not `Send`, so the session lives
//! on one dedicated thread; connection threads parse NDJSON lines and
//! exchange [`EngineMsg`]s with the loop over std mpsc channels — the
//! same process split vLLM makes between its API server and the worker.
//!
//! v2 requests stream every [`SeqEvent`] as its own line as the engine
//! produces it; a client that disconnects mid-stream gets its request
//! CANCELLED (the event sink's closed channel is the signal), so
//! abandoned streams stop burning arena blocks immediately.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{
    aborted_line, accepted_line, error_line, event_line, WireOp, WireResponse,
};
use crate::api::{RequestBuilder, RequestHandle, RequestId, SeqEvent, Session};
use crate::scheduler::{
    DecodeBackend, FinishReason, MultiEngine, Priority, Request, RequestOutput, SchedConfig,
};

/// Per-server wire defaults (a submit line may override `stream`;
/// `priority` applies to requests that do not name one) plus the
/// connection-hardening knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    pub default_stream: bool,
    pub default_priority: Priority,
    /// Longest inbound line the server will buffer. A peer that exceeds
    /// it gets one clean `{"error": ...}` line and the connection is
    /// closed — the rest of the oversized line is unrecoverable framing.
    pub max_line_bytes: usize,
    /// Per-connection read timeout while WAITING for a request line.
    /// A peer that trickles bytes slower than this (slow loris) is
    /// answered with an error line and disconnected. `None` = wait
    /// forever (the pre-hardening behavior; tests use it for clients
    /// that legitimately sit idle).
    pub read_timeout: Option<Duration>,
    /// Concurrent-connection cap. Connections beyond it are shed AT
    /// ACCEPT with a clean error line, protecting the live ones from
    /// thread/file-descriptor exhaustion.
    pub max_connections: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            default_stream: false,
            default_priority: Priority::Normal,
            max_line_bytes: 1 << 20,
            read_timeout: None,
            max_connections: 1024,
        }
    }
}

/// Per-request event-sink depth. The sink is a BOUNDED channel so a
/// client that stalls (stops reading without closing) cannot buffer
/// events without bound: once it falls this many events behind, the
/// engine cancels its request — same treatment as a disconnect.
pub const EVENT_CHANNEL_CAP: usize = 8192;

/// Messages connection threads send to the engine loop.
pub enum EngineMsg {
    Submit {
        builder: RequestBuilder,
        /// Replies with the server-assigned id, or a submit-time error.
        accepted: Sender<std::result::Result<u64, String>>,
        /// Event sink (bounded, [`EVENT_CHANNEL_CAP`]). Dropping the
        /// receiver — or letting it fill up — cancels the request.
        events: SyncSender<(u64, SeqEvent)>,
    },
    Abort {
        id: u64,
        ack: Sender<bool>,
    },
    /// Begin graceful shutdown: the session stops accepting submits
    /// (they fail fast with a clean error), live requests keep decoding
    /// until they drain or `deadline` elapses — at the deadline the
    /// stragglers are cancelled. The ack fires when the loop exits:
    /// `true` = everything drained on its own, `false` = the deadline
    /// forced cancellations.
    Shutdown {
        deadline: Duration,
        ack: Sender<bool>,
    },
}

/// Cloneable handle connection threads use to reach the engine loop.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<EngineMsg>,
}

impl EngineHandle {
    /// Submit and return the server-assigned id plus the event stream.
    pub fn submit_streaming(
        &self,
        builder: RequestBuilder,
    ) -> Result<(u64, Receiver<(u64, SeqEvent)>)> {
        let (etx, erx) = sync_channel(EVENT_CHANNEL_CAP);
        let (atx, arx) = channel();
        self.tx
            .send(EngineMsg::Submit { builder, accepted: atx, events: etx })
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        match arx.recv().context("engine loop dropped the submission")? {
            Ok(id) => Ok((id, erx)),
            Err(msg) => anyhow::bail!("submit rejected: {msg}"),
        }
    }

    /// Cancel by server-assigned id. `Ok(false)` = unknown/finished id
    /// (a clean no-op).
    pub fn abort(&self, id: u64) -> Result<bool> {
        let (atx, arx) = channel();
        self.tx
            .send(EngineMsg::Abort { id, ack: atx })
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        arx.recv().context("engine loop dropped the abort")
    }

    /// Gracefully shut the engine down: new submits are rejected
    /// immediately, live requests drain to completion (their streams end
    /// with a real `finished` line), and whatever outlasts `deadline` is
    /// cancelled. Blocks until the engine loop has exited and every
    /// event sink is flushed and closed. `Ok(true)` = drained cleanly,
    /// `Ok(false)` = the deadline forced cancellations.
    pub fn shutdown(&self, deadline: Duration) -> Result<bool> {
        let (atx, arx) = channel();
        self.tx
            .send(EngineMsg::Shutdown { deadline, ack: atx })
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        arx.recv().context("engine loop dropped the shutdown ack")
    }

    /// Legacy blocking one-shot: submit and wait for the terminal output.
    /// The engine assigns its own id; a nonzero caller id is echoed back
    /// in the output (v1 wire semantics).
    pub fn generate(&self, req: Request) -> Result<RequestOutput> {
        let caller_id = req.id;
        let (_, rx) = self.submit_streaming(builder_from_request(req))?;
        wait_for_finished(rx, caller_id)
    }
}

/// Drain an event stream to its terminal output, echoing `caller_id`
/// when nonzero (v1 semantics). Shared by [`EngineHandle::generate`] and
/// the TCP v1 line handler so the two one-shot paths cannot diverge.
fn wait_for_finished(rx: Receiver<(u64, SeqEvent)>, caller_id: u64) -> Result<RequestOutput> {
    for (_, ev) in rx {
        if let SeqEvent::Finished(mut out) = ev {
            if caller_id != 0 {
                out.id = caller_id;
            }
            return Ok(out);
        }
    }
    anyhow::bail!("request cancelled or engine loop gone")
}

/// Lower a legacy [`Request`] onto the builder surface. The legacy
/// `eos_token` folds into the stop-token set — `Request::is_stop` treats
/// them identically, so finish semantics are unchanged.
fn builder_from_request(req: Request) -> RequestBuilder {
    let mut stop = req.stop_tokens;
    if let Some(e) = req.eos_token {
        stop.push(e);
    }
    let mut b = RequestBuilder::new(req.prompt)
        .max_new_tokens(req.max_new_tokens)
        .stop_tokens(stop)
        .policy(req.policy)
        .budget(req.budget)
        .priority(req.priority)
        // one-shot: only the Finished event is ever read
        .stream_events(false);
    if let Some(d) = req.deadline_steps {
        b = b.deadline_steps(d);
    }
    b
}

/// A live stream: the session-side handle plus the connection-side sink.
type Sink<B> = (RequestHandle<B>, SyncSender<(u64, SeqEvent)>);

/// Forward freshly routed events from every live handle into its sink;
/// tear down streams that finished or whose client vanished or stalled.
fn deliver<B: DecodeBackend>(session: &Session<B>, sinks: &mut HashMap<u64, Sink<B>>) {
    let mut dead: Vec<u64> = Vec::new();
    for (&id, (handle, tx)) in sinks.iter_mut() {
        let mut done = false;
        for ev in handle.drain() {
            let is_fin = matches!(ev, SeqEvent::Finished(_));
            match tx.try_send((id, ev)) {
                Ok(()) => {
                    if is_fin {
                        done = true;
                    }
                }
                Err(e) => {
                    // disconnected, or stalled EVENT_CHANNEL_CAP events
                    // behind: either way, stop paying for it. A stalled
                    // client's stream is best-effort by design: if the
                    // dropped event was the terminal output, the client
                    // sees its stream end without a finished line.
                    let stalled = matches!(e, TrySendError::Full(_));
                    if is_fin && stalled {
                        log::warn!("req {id}: finished output dropped — sink stalled");
                    } else {
                        let why = if stalled { "stalled" } else { "closed" };
                        log::info!("req {id}: event sink {why} — cancelling");
                    }
                    handle.cancel();
                    done = true;
                    break;
                }
            }
        }
        if done {
            dead.push(id);
        }
    }
    for id in dead {
        if let Some((handle, _)) = sinks.remove(&id) {
            session.forget(handle.id());
        }
    }
}

/// Run the engine loop on the CURRENT thread. Returns when `rx`
/// disconnects and all work is drained.
pub fn run_engine_loop<B: DecodeBackend>(
    session: Session<B>,
    rx: Receiver<EngineMsg>,
) -> Result<()> {
    let mut sinks: HashMap<u64, Sink<B>> = HashMap::new();
    let mut disconnected = false;
    // Armed by EngineMsg::Shutdown: the drain deadline plus every caller
    // waiting on the ack (concurrent shutdowns coalesce onto the
    // EARLIEST deadline; all of them are acked when the loop exits).
    let mut shutdown: Option<(Instant, Vec<Sender<bool>>)> = None;
    loop {
        // Drain the inbox without blocking while there is work; block when
        // idle to avoid spinning. Never block once shutdown is armed —
        // the loop must keep watching the drain deadline.
        loop {
            let msg = if session.is_idle() && !disconnected && shutdown.is_none() {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some(EngineMsg::Submit { builder, accepted, events }) => {
                    match session.submit(builder) {
                        Ok(handle) => {
                            let id = handle.id().raw();
                            let _ = accepted.send(Ok(id));
                            sinks.insert(id, (handle, events));
                            // a submit-time rejection (e.g. zero budget)
                            // emits Finished with no step and keeps the
                            // session idle — deliver NOW, before this
                            // loop blocks on recv again
                            deliver(&session, &mut sinks);
                        }
                        Err(e) => {
                            let _ = accepted.send(Err(format!("{e:#}")));
                        }
                    }
                }
                Some(EngineMsg::Abort { id, ack }) => {
                    let ok = session.cancel(RequestId(id));
                    if ok {
                        // the sink just goes away: an aborted request
                        // emits no Finished event (the conn thread turns
                        // the closed channel into its `aborted` notice)
                        if let Some((handle, _)) = sinks.remove(&id) {
                            session.forget(handle.id());
                        }
                    }
                    let _ = ack.send(ok);
                }
                Some(EngineMsg::Shutdown { deadline, ack }) => {
                    session.begin_shutdown();
                    let end = Instant::now() + deadline;
                    match &mut shutdown {
                        Some((e, acks)) => {
                            *e = (*e).min(end);
                            acks.push(ack);
                        }
                        None => shutdown = Some((end, vec![ack])),
                    }
                }
                None => break,
            }
        }
        if let Some((end, _)) = &shutdown {
            let drained = session.is_idle();
            if drained || Instant::now() >= *end {
                if !drained {
                    log::warn!(
                        "shutdown deadline passed with {} live requests — cancelling",
                        session.pending() + session.running()
                    );
                    for id in session.with_scheduler(|s| s.live_ids()) {
                        session.cancel(RequestId(id));
                    }
                }
                // flush anything routed this round, then close every sink
                // BEFORE acking, so by the time shutdown() returns each
                // streaming connection has seen its stream end
                deliver(&session, &mut sinks);
                drop(sinks);
                let (_, acks) = shutdown.take().expect("shutdown just matched");
                for ack in acks {
                    let _ = ack.send(drained);
                }
                return Ok(());
            }
        }
        // (submit-time rejections were already delivered inline above)
        if session.is_idle() {
            if disconnected {
                return Ok(());
            }
            continue;
        }
        session.step()?;
        deliver(&session, &mut sinks);
    }
}

/// Run the engine loop over a multi-worker [`MultiEngine`] on the
/// CURRENT thread: the same [`EngineMsg`] protocol as
/// [`run_engine_loop`] — same submit/abort/shutdown semantics, same
/// bounded-sink stall handling — so [`EngineHandle`] and every
/// connection thread are oblivious to the worker count. Ids stay
/// globally monotonic (the engine stamps them); cancel fans out to the
/// owning worker; shutdown drains ALL workers to one deadline.
pub fn run_multi_engine_loop<B>(mut engine: MultiEngine<B>, rx: Receiver<EngineMsg>) -> Result<()>
where
    B: DecodeBackend + Send + 'static,
    B::Seq: Send + 'static,
    B::Snapshot: Send + 'static,
    B::PrefillPlan: Send + 'static,
{
    let mut sinks: HashMap<u64, SyncSender<(u64, SeqEvent)>> = HashMap::new();
    let mut disconnected = false;
    let mut draining = false;
    // Same coalescing rule as the single-engine loop: concurrent
    // shutdowns share the EARLIEST deadline, every ack fires on exit.
    let mut shutdown: Option<(Instant, Vec<Sender<bool>>)> = None;
    loop {
        // Drain the control inbox without blocking — the workers decode
        // on their own threads; this loop only places and routes.
        loop {
            match rx.try_recv() {
                Ok(EngineMsg::Submit { builder, accepted, events }) => {
                    if draining {
                        let _ = accepted
                            .send(Err("session shutting down; not accepting new requests".into()));
                        continue;
                    }
                    match engine.submit_builder(builder) {
                        Ok(id) => {
                            let _ = accepted.send(Ok(id.raw()));
                            sinks.insert(id.raw(), events);
                        }
                        Err(e) => {
                            let _ = accepted.send(Err(format!("{e:#}")));
                        }
                    }
                }
                Ok(EngineMsg::Abort { id, ack }) => {
                    let ok = engine.cancel(id);
                    if ok {
                        // aborted requests emit no Finished event: dropping
                        // the sink ends the stream, and the conn thread
                        // turns that into its `aborted` notice
                        sinks.remove(&id);
                    }
                    let _ = ack.send(ok);
                }
                Ok(EngineMsg::Shutdown { deadline, ack }) => {
                    draining = true;
                    let end = Instant::now() + deadline;
                    match &mut shutdown {
                        Some((e, acks)) => {
                            *e = (*e).min(end);
                            acks.push(ack);
                        }
                        None => shutdown = Some((end, vec![ack])),
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Route worker events to their sinks. The bounded wait paces the
        // loop; it returns early the moment an event lands.
        while let Some((id, ev)) = engine.next_event(Duration::from_millis(2)) {
            let is_fin = matches!(ev, SeqEvent::Finished(_));
            let Some(tx) = sinks.get(&id) else { continue };
            match tx.try_send((id, ev)) {
                Ok(()) => {
                    if is_fin {
                        sinks.remove(&id);
                    }
                }
                Err(e) => {
                    // disconnected, or stalled EVENT_CHANNEL_CAP events
                    // behind — same best-effort contract as the
                    // single-engine loop
                    let stalled = matches!(e, TrySendError::Full(_));
                    if is_fin && stalled {
                        log::warn!("req {id}: finished output dropped — sink stalled");
                    } else {
                        let why = if stalled { "stalled" } else { "closed" };
                        log::info!("req {id}: event sink {why} — cancelling");
                    }
                    if !is_fin {
                        engine.cancel(id);
                    }
                    sinks.remove(&id);
                }
            }
        }
        if let Some((end, _)) = &shutdown {
            let drained = engine.inflight() == 0;
            if drained || Instant::now() >= *end {
                if !drained {
                    log::warn!(
                        "shutdown deadline passed with {} live requests — cancelling",
                        engine.inflight()
                    );
                    for id in sinks.keys().copied().collect::<Vec<_>>() {
                        engine.cancel(id);
                    }
                }
                // join the workers; any terminal output that raced the
                // teardown still reaches its sink before the streams close
                let (report, _) = engine.shutdown(Duration::from_millis(50));
                for out in report.leftover {
                    if let Some(tx) = sinks.remove(&out.id) {
                        let _ = tx.try_send((out.id, SeqEvent::Finished(out)));
                    }
                }
                drop(sinks);
                let (_, acks) = shutdown.take().expect("shutdown just matched");
                for ack in acks {
                    let _ = ack.send(drained);
                }
                return Ok(());
            }
        }
        if disconnected && shutdown.is_none() && engine.inflight() == 0 {
            let _ = engine.shutdown(Duration::from_millis(50));
            return Ok(());
        }
        if engine.inflight() == 0 {
            // fully idle: cheap park between inbox polls
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Spawn the engine loop over the always-built deterministic sim backend
/// (no PJRT, no artifacts). What `paged-eviction serve --backend sim`
/// and the tier-1 server tests run. `cfg.workers > 1` serves the same
/// wire surface from the multi-worker engine.
pub fn spawn_sim_engine(
    cfg: SchedConfig,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel();
    let join = if cfg.workers > 1 {
        let engine = MultiEngine::new_sim(cfg);
        std::thread::Builder::new()
            .name("engine-loop".into())
            .spawn(move || {
                if let Err(e) = run_multi_engine_loop(engine, rx) {
                    log::error!("engine loop died: {e:#}");
                }
            })?
    } else {
        let session = Session::new_sim(cfg);
        std::thread::Builder::new()
            .name("engine-loop".into())
            .spawn(move || {
                if let Err(e) = run_engine_loop(session, rx) {
                    log::error!("engine loop died: {e:#}");
                }
            })?
    };
    Ok((EngineHandle { tx }, join))
}

/// Spawn the sim engine loop with a deterministic fault injector wrapped
/// around the backend (see [`crate::runtime::FaultPlan`]). What
/// `serve --backend sim --faults SPEC` and the chaos tests run. Under
/// `cfg.workers > 1` every worker gets its own clone of the plan, so
/// fault lanes stay per-worker-stable.
pub fn spawn_sim_engine_faulty(
    cfg: SchedConfig,
    plan: crate::runtime::FaultPlan,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel();
    let join = if cfg.workers > 1 {
        let engine = MultiEngine::new_sim_faulty(cfg, plan);
        std::thread::Builder::new()
            .name("engine-loop".into())
            .spawn(move || {
                if let Err(e) = run_multi_engine_loop(engine, rx) {
                    log::error!("engine loop died: {e:#}");
                }
            })?
    } else {
        let session = Session::new_sim_faulty(cfg, plan);
        std::thread::Builder::new()
            .name("engine-loop".into())
            .spawn(move || {
                if let Err(e) = run_engine_loop(session, rx) {
                    log::error!("engine loop died: {e:#}");
                }
            })?
    };
    Ok((EngineHandle { tx }, join))
}

/// Spawn the PJRT engine loop on its own thread and return a handle.
/// `artifacts_dir` is loaded inside the thread (Engine is not Send).
#[cfg(feature = "xla")]
pub fn spawn_engine(
    artifacts_dir: std::path::PathBuf,
    cfg: SchedConfig,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    use crate::runtime::Engine;

    let (tx, rx) = channel();
    let (ready_tx, ready_rx) = channel();
    let join = std::thread::Builder::new()
        .name("engine-loop".into())
        .spawn(move || {
            let engine = match Engine::new(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let run = move || -> Result<()> {
                let sched = crate::scheduler::Scheduler::new(&engine, cfg)?;
                run_engine_loop(Session::from_scheduler(sched), rx)
            };
            if let Err(e) = run() {
                log::error!("engine loop died: {e:#}");
            }
        })?;
    match ready_rx.recv() {
        Ok(Ok(())) => Ok((EngineHandle { tx }, join)),
        Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
        Err(_) => anyhow::bail!("engine thread vanished"),
    }
}

/// Cloneable stop signal for [`serve_until`]: trigger it from any thread
/// and the accept loop returns after its next poll tick.
#[derive(Clone, Default)]
pub struct ShutdownFlag(Arc<AtomicBool>);

impl ShutdownFlag {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn trigger(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_triggered(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Decrements the live-connection count when its thread exits, however
/// the connection ends (clean close, error, panic unwind).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Accept loop: NDJSON over TCP, one thread per connection. Keeps
/// accepting until `stop` is triggered; a transient accept failure
/// (EMFILE, ECONNABORTED, ...) is logged and backed off, never fatal —
/// one bad accept must not take down every established connection.
pub fn serve_until(
    listener: TcpListener,
    handle: EngineHandle,
    opts: ServeOpts,
    stop: ShutdownFlag,
) -> Result<()> {
    log::info!("listening on {}", listener.local_addr()?);
    // Nonblocking so the loop can poll the stop flag between accepts.
    listener.set_nonblocking(true)?;
    let live = Arc::new(AtomicUsize::new(0));
    while !stop.is_triggered() {
        let (conn, _) = match listener.accept() {
            Ok(c) => c,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(e) => {
                log::warn!("accept failed: {e} — backing off");
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        // accepted sockets can inherit the listener's nonblocking mode
        if let Err(e) = conn.set_nonblocking(false) {
            log::warn!("set_nonblocking failed: {e}");
            continue;
        }
        if live.fetch_add(1, Ordering::SeqCst) >= opts.max_connections {
            live.fetch_sub(1, Ordering::SeqCst);
            let mut conn = conn;
            let _ = writeln!(conn, "{}", error_line("server at connection capacity"));
            continue;
        }
        let guard = ConnGuard(Arc::clone(&live));
        let h = handle.clone();
        std::thread::spawn(move || {
            let _guard = guard;
            if let Err(e) = handle_conn(conn, h, opts) {
                log::debug!("connection closed: {e:#}");
            }
        });
    }
    log::info!("accept loop stopped");
    Ok(())
}

/// Accept loop that never stops (CLI default): [`serve_until`] with a
/// flag nobody triggers.
pub fn serve_forever(
    listener: TcpListener,
    handle: EngineHandle,
    opts: ServeOpts,
) -> Result<()> {
    serve_until(listener, handle, opts, ShutdownFlag::new())
}

/// One inbound read on a hardened connection.
enum ReadLine {
    Line(String),
    Eof,
    /// The line exceeded `max_line_bytes`; its excess was consumed but
    /// NOT buffered (a peer cannot make the server hold its flood).
    TooLong,
    /// The socket's read timeout elapsed mid-wait (slow loris).
    TimedOut,
}

/// Read one `\n`-terminated line without ever buffering more than
/// `max_bytes` of it — the bounded replacement for `BufRead::lines()`,
/// which grows its line buffer to whatever the peer sends.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    max_bytes: usize,
) -> std::io::Result<ReadLine> {
    let mut line: Vec<u8> = Vec::new();
    let mut overflowed = false;
    loop {
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(ReadLine::TimedOut);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if buf.is_empty() {
            // EOF; an unterminated final line still gets parsed
            return Ok(match (overflowed, line.is_empty()) {
                (true, _) => ReadLine::TooLong,
                (false, true) => ReadLine::Eof,
                (false, false) => ReadLine::Line(String::from_utf8_lossy(&line).into_owned()),
            });
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if !overflowed {
                    line.extend_from_slice(&buf[..pos]);
                }
                reader.consume(pos + 1);
                if overflowed || line.len() > max_bytes {
                    return Ok(ReadLine::TooLong);
                }
                let mut s = String::from_utf8_lossy(&line).into_owned();
                if s.ends_with('\r') {
                    s.pop();
                }
                return Ok(ReadLine::Line(s));
            }
            None => {
                let n = buf.len();
                if !overflowed {
                    line.extend_from_slice(buf);
                    if line.len() > max_bytes {
                        overflowed = true;
                        line = Vec::new(); // stop holding the flood
                    }
                }
                reader.consume(n);
            }
        }
    }
}

fn handle_conn(stream: TcpStream, handle: EngineHandle, opts: ServeOpts) -> Result<()> {
    let peer = stream.peer_addr()?;
    stream.set_read_timeout(opts.read_timeout)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_bounded_line(&mut reader, opts.max_line_bytes)? {
            ReadLine::Line(l) => l,
            ReadLine::Eof => break,
            ReadLine::TooLong => {
                // answer cleanly, then hang up: the rest of the oversized
                // line is unrecoverable framing
                let msg = format!("line exceeds {} bytes", opts.max_line_bytes);
                writeln!(writer, "{}", error_line(&msg))?;
                anyhow::bail!("peer {peer} sent an oversized line");
            }
            ReadLine::TimedOut => {
                let _ = writeln!(writer, "{}", error_line("read timeout"));
                anyhow::bail!("peer {peer} hit the read timeout");
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match WireOp::parse(&line, opts.default_stream, opts.default_priority) {
            Ok(WireOp::Submit { builder, stream: want_stream }) => {
                let (id, rx) = match handle.submit_streaming(builder) {
                    Ok(x) => x,
                    Err(e) => {
                        writeln!(writer, "{}", error_line(&format!("{e:#}")))?;
                        continue;
                    }
                };
                writeln!(writer, "{}", accepted_line(id))?;
                let mut finished = false;
                for (_, ev) in rx {
                    if want_stream {
                        writeln!(writer, "{}", event_line(id, &ev))?;
                    } else if let SeqEvent::Finished(out) = &ev {
                        writeln!(writer, "{}", WireResponse(out.clone()).to_line())?;
                    }
                    if matches!(ev, SeqEvent::Finished(_)) {
                        finished = true;
                        break;
                    }
                }
                if !finished {
                    // The stream ended without a finished line: either the
                    // request was aborted/stall-cancelled (engine alive —
                    // close with the aborted notice) or the engine loop
                    // died (tell the client the truth, not "aborted").
                    // NOTE: a streaming connection reads its own stream
                    // until it ends, so the abort must come from a
                    // DIFFERENT connection.
                    match handle.abort(id) {
                        Ok(_) => writeln!(writer, "{}", aborted_line(id, true))?,
                        Err(_) => writeln!(
                            writer,
                            "{}",
                            error_line("engine stopped before the request finished")
                        )?,
                    }
                }
            }
            Ok(WireOp::Abort { id }) => {
                let ok = handle.abort(id)?;
                writeln!(writer, "{}", aborted_line(id, ok))?;
            }
            Ok(WireOp::Legacy { id, builder }) => {
                let prompt_len = builder.prompt_len();
                let result = handle
                    .submit_streaming(builder)
                    .and_then(|(_, rx)| wait_for_finished(rx, id));
                let out = result.unwrap_or_else(|e| {
                    // v1 contract: failures come back as a response line
                    // CARRYING the caller's id (finish "error"), so
                    // id-demultiplexing clients are never left hanging
                    log::debug!("legacy req {id}: {e:#}");
                    RequestOutput {
                        id,
                        tokens: Vec::new(),
                        policy: String::new(), // never admitted: no policy ran
                        finish: FinishReason::Error,
                        ttft_s: 0.0,
                        tpot_s: 0.0,
                        prompt_len,
                        live_cache_tokens: 0,
                        preemptions: 0,
                        swaps: 0,
                        retries: 0,
                        cache_stats: Default::default(),
                    }
                });
                writeln!(writer, "{}", WireResponse(out).to_line())?;
            }
            Err(e) => {
                writeln!(writer, "{}", error_line(&e.to_string()))?;
            }
        }
    }
    log::debug!("peer {peer} disconnected");
    Ok(())
}
