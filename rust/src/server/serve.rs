//! The engine loop + TCP frontend.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::protocol::{WireRequest, WireResponse};
use crate::scheduler::{Request, RequestOutput, SchedConfig, Scheduler};
use crate::runtime::Engine;

type ReplyTx = Sender<RequestOutput>;

/// Cloneable handle connection threads use to reach the engine loop.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<(Request, ReplyTx)>,
}

impl EngineHandle {
    /// Submit a request and block until it completes.
    pub fn generate(&self, req: Request) -> Result<RequestOutput> {
        let (rtx, rrx) = channel();
        self.tx
            .send((req, rtx))
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        rrx.recv().context("engine loop dropped the request")
    }
}

/// Run the engine loop on the CURRENT thread (PJRT handles are not Send).
/// Returns when `rx` disconnects and all work is drained.
pub fn engine_loop(
    engine: &Engine,
    cfg: SchedConfig,
    rx: Receiver<(Request, ReplyTx)>,
) -> Result<()> {
    let mut sched = Scheduler::new(engine, cfg)?;
    let mut waiters: std::collections::HashMap<u64, ReplyTx> = Default::default();
    let mut disconnected = false;
    loop {
        // Drain the inbox without blocking while there is work; block when
        // idle to avoid spinning.
        loop {
            let msg = if sched.is_idle() && !disconnected {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some((req, reply)) => {
                    waiters.insert(req.id, reply);
                    sched.submit(req);
                }
                None => break,
            }
        }
        if sched.is_idle() {
            if disconnected {
                return Ok(());
            }
            continue;
        }
        sched.step()?;
        for out in sched.take_finished() {
            if let Some(tx) = waiters.remove(&out.id) {
                let _ = tx.send(out);
            }
        }
    }
}

/// Spawn the engine loop on its own thread and return a handle.
/// `artifacts_dir` is loaded inside the thread (Engine is not Send).
pub fn spawn_engine(
    artifacts_dir: std::path::PathBuf,
    cfg: SchedConfig,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel();
    let (ready_tx, ready_rx) = channel();
    let join = std::thread::Builder::new()
        .name("engine-loop".into())
        .spawn(move || {
            let engine = match Engine::new(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            if let Err(e) = engine_loop(&engine, cfg, rx) {
                log::error!("engine loop died: {e:#}");
            }
        })?;
    match ready_rx.recv() {
        Ok(Ok(())) => Ok((EngineHandle { tx }, join)),
        Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
        Err(_) => anyhow::bail!("engine thread vanished"),
    }
}

/// Accept loop: JSON-lines over TCP, one thread per connection.
pub fn serve_forever(
    listener: TcpListener,
    handle: EngineHandle,
    next_id: Arc<Mutex<u64>>,
) -> Result<()> {
    log::info!("listening on {}", listener.local_addr()?);
    for conn in listener.incoming() {
        let conn = conn?;
        let h = handle.clone();
        let ids = next_id.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(conn, h, ids) {
                log::debug!("connection closed: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    handle: EngineHandle,
    next_id: Arc<Mutex<u64>>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match WireRequest::parse(&line) {
            Ok(WireRequest(mut req)) => {
                if req.id == 0 {
                    let mut g = next_id.lock().unwrap();
                    *g += 1;
                    req.id = *g;
                }
                let out = handle.generate(req)?;
                writeln!(writer, "{}", WireResponse(out).to_line())?;
            }
            Err(e) => {
                writeln!(writer, "{{\"error\": \"{}\"}}", e.to_string().replace('"', "'"))?;
            }
        }
    }
    log::debug!("peer {peer} disconnected");
    Ok(())
}
