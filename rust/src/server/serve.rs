//! The engine loop + TCP frontend.
//!
//! The engine loop owns an [`api::Session`] and is generic over the
//! decode backend, so the IDENTICAL loop serves the always-built sim
//! backend ([`spawn_sim_engine`], tier-1 tested over real TCP in
//! `tests/serve_v2.rs`) and the PJRT runtime ([`spawn_engine`],
//! `--features xla`). PJRT handles are not `Send`, so the session lives
//! on one dedicated thread; connection threads parse NDJSON lines and
//! exchange [`EngineMsg`]s with the loop over std mpsc channels — the
//! same process split vLLM makes between its API server and the worker.
//!
//! v2 requests stream every [`SeqEvent`] as its own line as the engine
//! produces it; a client that disconnects mid-stream gets its request
//! CANCELLED (the event sink's closed channel is the signal), so
//! abandoned streams stop burning arena blocks immediately.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};

use anyhow::{Context, Result};

use super::protocol::{
    aborted_line, accepted_line, error_line, event_line, WireOp, WireResponse,
};
use crate::api::{RequestBuilder, RequestHandle, RequestId, SeqEvent, Session};
use crate::scheduler::{
    DecodeBackend, FinishReason, Priority, Request, RequestOutput, SchedConfig,
};

/// Per-server wire defaults (a submit line may override `stream`;
/// `priority` applies to requests that do not name one).
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    pub default_stream: bool,
    pub default_priority: Priority,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { default_stream: false, default_priority: Priority::Normal }
    }
}

/// Per-request event-sink depth. The sink is a BOUNDED channel so a
/// client that stalls (stops reading without closing) cannot buffer
/// events without bound: once it falls this many events behind, the
/// engine cancels its request — same treatment as a disconnect.
pub const EVENT_CHANNEL_CAP: usize = 8192;

/// Messages connection threads send to the engine loop.
pub enum EngineMsg {
    Submit {
        builder: RequestBuilder,
        /// Replies with the server-assigned id, or a submit-time error.
        accepted: Sender<std::result::Result<u64, String>>,
        /// Event sink (bounded, [`EVENT_CHANNEL_CAP`]). Dropping the
        /// receiver — or letting it fill up — cancels the request.
        events: SyncSender<(u64, SeqEvent)>,
    },
    Abort {
        id: u64,
        ack: Sender<bool>,
    },
}

/// Cloneable handle connection threads use to reach the engine loop.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<EngineMsg>,
}

impl EngineHandle {
    /// Submit and return the server-assigned id plus the event stream.
    pub fn submit_streaming(
        &self,
        builder: RequestBuilder,
    ) -> Result<(u64, Receiver<(u64, SeqEvent)>)> {
        let (etx, erx) = sync_channel(EVENT_CHANNEL_CAP);
        let (atx, arx) = channel();
        self.tx
            .send(EngineMsg::Submit { builder, accepted: atx, events: etx })
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        match arx.recv().context("engine loop dropped the submission")? {
            Ok(id) => Ok((id, erx)),
            Err(msg) => anyhow::bail!("submit rejected: {msg}"),
        }
    }

    /// Cancel by server-assigned id. `Ok(false)` = unknown/finished id
    /// (a clean no-op).
    pub fn abort(&self, id: u64) -> Result<bool> {
        let (atx, arx) = channel();
        self.tx
            .send(EngineMsg::Abort { id, ack: atx })
            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
        arx.recv().context("engine loop dropped the abort")
    }

    /// Legacy blocking one-shot: submit and wait for the terminal output.
    /// The engine assigns its own id; a nonzero caller id is echoed back
    /// in the output (v1 wire semantics).
    pub fn generate(&self, req: Request) -> Result<RequestOutput> {
        let caller_id = req.id;
        let (_, rx) = self.submit_streaming(builder_from_request(req))?;
        wait_for_finished(rx, caller_id)
    }
}

/// Drain an event stream to its terminal output, echoing `caller_id`
/// when nonzero (v1 semantics). Shared by [`EngineHandle::generate`] and
/// the TCP v1 line handler so the two one-shot paths cannot diverge.
fn wait_for_finished(rx: Receiver<(u64, SeqEvent)>, caller_id: u64) -> Result<RequestOutput> {
    for (_, ev) in rx {
        if let SeqEvent::Finished(mut out) = ev {
            if caller_id != 0 {
                out.id = caller_id;
            }
            return Ok(out);
        }
    }
    anyhow::bail!("request cancelled or engine loop gone")
}

/// Lower a legacy [`Request`] onto the builder surface. The legacy
/// `eos_token` folds into the stop-token set — `Request::is_stop` treats
/// them identically, so finish semantics are unchanged.
fn builder_from_request(req: Request) -> RequestBuilder {
    let mut stop = req.stop_tokens;
    if let Some(e) = req.eos_token {
        stop.push(e);
    }
    let mut b = RequestBuilder::new(req.prompt)
        .max_new_tokens(req.max_new_tokens)
        .stop_tokens(stop)
        .policy(req.policy)
        .budget(req.budget)
        .priority(req.priority)
        // one-shot: only the Finished event is ever read
        .stream_events(false);
    if let Some(d) = req.deadline_steps {
        b = b.deadline_steps(d);
    }
    b
}

/// A live stream: the session-side handle plus the connection-side sink.
type Sink<B> = (RequestHandle<B>, SyncSender<(u64, SeqEvent)>);

/// Forward freshly routed events from every live handle into its sink;
/// tear down streams that finished or whose client vanished or stalled.
fn deliver<B: DecodeBackend>(session: &Session<B>, sinks: &mut HashMap<u64, Sink<B>>) {
    let mut dead: Vec<u64> = Vec::new();
    for (&id, (handle, tx)) in sinks.iter_mut() {
        let mut done = false;
        for ev in handle.drain() {
            let is_fin = matches!(ev, SeqEvent::Finished(_));
            match tx.try_send((id, ev)) {
                Ok(()) => {
                    if is_fin {
                        done = true;
                    }
                }
                Err(e) => {
                    // disconnected, or stalled EVENT_CHANNEL_CAP events
                    // behind: either way, stop paying for it. A stalled
                    // client's stream is best-effort by design: if the
                    // dropped event was the terminal output, the client
                    // sees its stream end without a finished line.
                    let stalled = matches!(e, TrySendError::Full(_));
                    if is_fin && stalled {
                        log::warn!("req {id}: finished output dropped — sink stalled");
                    } else {
                        let why = if stalled { "stalled" } else { "closed" };
                        log::info!("req {id}: event sink {why} — cancelling");
                    }
                    handle.cancel();
                    done = true;
                    break;
                }
            }
        }
        if done {
            dead.push(id);
        }
    }
    for id in dead {
        if let Some((handle, _)) = sinks.remove(&id) {
            session.forget(handle.id());
        }
    }
}

/// Run the engine loop on the CURRENT thread. Returns when `rx`
/// disconnects and all work is drained.
pub fn run_engine_loop<B: DecodeBackend>(
    session: Session<B>,
    rx: Receiver<EngineMsg>,
) -> Result<()> {
    let mut sinks: HashMap<u64, Sink<B>> = HashMap::new();
    let mut disconnected = false;
    loop {
        // Drain the inbox without blocking while there is work; block when
        // idle to avoid spinning.
        loop {
            let msg = if session.is_idle() && !disconnected {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some(EngineMsg::Submit { builder, accepted, events }) => {
                    match session.submit(builder) {
                        Ok(handle) => {
                            let id = handle.id().raw();
                            let _ = accepted.send(Ok(id));
                            sinks.insert(id, (handle, events));
                            // a submit-time rejection (e.g. zero budget)
                            // emits Finished with no step and keeps the
                            // session idle — deliver NOW, before this
                            // loop blocks on recv again
                            deliver(&session, &mut sinks);
                        }
                        Err(e) => {
                            let _ = accepted.send(Err(format!("{e:#}")));
                        }
                    }
                }
                Some(EngineMsg::Abort { id, ack }) => {
                    let ok = session.cancel(RequestId(id));
                    if ok {
                        // the sink just goes away: an aborted request
                        // emits no Finished event (the conn thread turns
                        // the closed channel into its `aborted` notice)
                        if let Some((handle, _)) = sinks.remove(&id) {
                            session.forget(handle.id());
                        }
                    }
                    let _ = ack.send(ok);
                }
                None => break,
            }
        }
        // (submit-time rejections were already delivered inline above)
        if session.is_idle() {
            if disconnected {
                return Ok(());
            }
            continue;
        }
        session.step()?;
        deliver(&session, &mut sinks);
    }
}

/// Spawn the engine loop over the always-built deterministic sim backend
/// (no PJRT, no artifacts). What `paged-eviction serve --backend sim`
/// and the tier-1 server tests run.
pub fn spawn_sim_engine(
    cfg: SchedConfig,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel();
    let session = Session::new_sim(cfg);
    let join = std::thread::Builder::new()
        .name("engine-loop".into())
        .spawn(move || {
            if let Err(e) = run_engine_loop(session, rx) {
                log::error!("engine loop died: {e:#}");
            }
        })?;
    Ok((EngineHandle { tx }, join))
}

/// Spawn the PJRT engine loop on its own thread and return a handle.
/// `artifacts_dir` is loaded inside the thread (Engine is not Send).
#[cfg(feature = "xla")]
pub fn spawn_engine(
    artifacts_dir: std::path::PathBuf,
    cfg: SchedConfig,
) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
    use crate::runtime::Engine;

    let (tx, rx) = channel();
    let (ready_tx, ready_rx) = channel();
    let join = std::thread::Builder::new()
        .name("engine-loop".into())
        .spawn(move || {
            let engine = match Engine::new(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let run = move || -> Result<()> {
                let sched = crate::scheduler::Scheduler::new(&engine, cfg)?;
                run_engine_loop(Session::from_scheduler(sched), rx)
            };
            if let Err(e) = run() {
                log::error!("engine loop died: {e:#}");
            }
        })?;
    match ready_rx.recv() {
        Ok(Ok(())) => Ok((EngineHandle { tx }, join)),
        Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
        Err(_) => anyhow::bail!("engine thread vanished"),
    }
}

/// Accept loop: NDJSON over TCP, one thread per connection.
pub fn serve_forever(
    listener: TcpListener,
    handle: EngineHandle,
    opts: ServeOpts,
) -> Result<()> {
    log::info!("listening on {}", listener.local_addr()?);
    for conn in listener.incoming() {
        let conn = conn?;
        let h = handle.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(conn, h, opts) {
                log::debug!("connection closed: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: EngineHandle, opts: ServeOpts) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match WireOp::parse(&line, opts.default_stream, opts.default_priority) {
            Ok(WireOp::Submit { builder, stream: want_stream }) => {
                let (id, rx) = match handle.submit_streaming(builder) {
                    Ok(x) => x,
                    Err(e) => {
                        writeln!(writer, "{}", error_line(&format!("{e:#}")))?;
                        continue;
                    }
                };
                writeln!(writer, "{}", accepted_line(id))?;
                let mut finished = false;
                for (_, ev) in rx {
                    if want_stream {
                        writeln!(writer, "{}", event_line(id, &ev))?;
                    } else if let SeqEvent::Finished(out) = &ev {
                        writeln!(writer, "{}", WireResponse(out.clone()).to_line())?;
                    }
                    if matches!(ev, SeqEvent::Finished(_)) {
                        finished = true;
                        break;
                    }
                }
                if !finished {
                    // The stream ended without a finished line: either the
                    // request was aborted/stall-cancelled (engine alive —
                    // close with the aborted notice) or the engine loop
                    // died (tell the client the truth, not "aborted").
                    // NOTE: a streaming connection reads its own stream
                    // until it ends, so the abort must come from a
                    // DIFFERENT connection.
                    match handle.abort(id) {
                        Ok(_) => writeln!(writer, "{}", aborted_line(id, true))?,
                        Err(_) => writeln!(
                            writer,
                            "{}",
                            error_line("engine stopped before the request finished")
                        )?,
                    }
                }
            }
            Ok(WireOp::Abort { id }) => {
                let ok = handle.abort(id)?;
                writeln!(writer, "{}", aborted_line(id, ok))?;
            }
            Ok(WireOp::Legacy { id, builder }) => {
                let prompt_len = builder.prompt_len();
                let result = handle
                    .submit_streaming(builder)
                    .and_then(|(_, rx)| wait_for_finished(rx, id));
                let out = result.unwrap_or_else(|e| {
                    // v1 contract: failures come back as a response line
                    // CARRYING the caller's id (finish "error"), so
                    // id-demultiplexing clients are never left hanging
                    log::debug!("legacy req {id}: {e:#}");
                    RequestOutput {
                        id,
                        tokens: Vec::new(),
                        finish: FinishReason::Error,
                        ttft_s: 0.0,
                        tpot_s: 0.0,
                        prompt_len,
                        live_cache_tokens: 0,
                        preemptions: 0,
                        swaps: 0,
                        cache_stats: Default::default(),
                    }
                });
                writeln!(writer, "{}", WireResponse(out).to_line())?;
            }
            Err(e) => {
                writeln!(writer, "{}", error_line(&e.to_string()))?;
            }
        }
    }
    log::debug!("peer {peer} disconnected");
    Ok(())
}
