//! Wire protocol: one JSON object per line, request/response.
//!
//! Request fields:
//!   {"id": 1, "text": "..."} or {"id": 1, "prompt": [ids...]},
//!   optional: "max_new_tokens" (default 32), "budget" (default 1024),
//!             "policy" ("paged"|"full"|"streaming"|...), "eos" (token id)
//! Response:
//!   {"id": 1, "tokens": [...], "text": "...", "finish": "length"|"eos",
//!    "ttft_ms": .., "tpot_ms": .., "live_cache_tokens": ..,
//!    "preemptions": .., "swaps": .., "prefix_hit_blocks": ..,
//!    "cow_copies": ..}

use anyhow::{Context, Result};

use crate::scheduler::{FinishReason, Request, RequestOutput};
use crate::tokenizer;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WireRequest(pub Request);

impl WireRequest {
    pub fn parse(line: &str) -> Result<WireRequest> {
        let j = Json::parse(line).context("bad request json")?;
        let id = j.get("id").and_then(|v| v.as_u64()).unwrap_or(0);
        let prompt: Vec<u32> = if let Some(arr) = j.get("prompt").and_then(|v| v.as_arr()) {
            arr.iter()
                .map(|v| v.as_usize().map(|x| x as u32))
                .collect::<Option<Vec<u32>>>()
                .context("prompt must be an int array")?
        } else if let Some(text) = j.get("text").and_then(|v| v.as_str()) {
            tokenizer::encode(text)
        } else {
            anyhow::bail!("request needs 'prompt' (ids) or 'text'");
        };
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut req = Request::new(id, prompt, 32);
        if let Some(m) = j.get("max_new_tokens").and_then(|v| v.as_usize()) {
            req.max_new_tokens = m.max(1);
        }
        if let Some(b) = j.get("budget").and_then(|v| v.as_usize()) {
            req.budget = b;
        }
        if let Some(p) = j.get("policy").and_then(|v| v.as_str()) {
            req.policy = p.to_string();
        }
        if let Some(e) = j.get("eos").and_then(|v| v.as_usize()) {
            req.eos_token = Some(e as u32);
        }
        Ok(WireRequest(req))
    }
}

trait JsonU64 {
    fn as_u64(&self) -> Option<u64>;
}

impl JsonU64 for Json {
    fn as_u64(&self) -> Option<u64> {
        self.as_i64().filter(|v| *v >= 0).map(|v| v as u64)
    }
}

#[derive(Debug, Clone)]
pub struct WireResponse(pub RequestOutput);

impl WireResponse {
    pub fn to_line(&self) -> String {
        let o = &self.0;
        let finish = match o.finish {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "length",
            FinishReason::Error => "error",
        };
        Json::obj(vec![
            ("id", Json::num(o.id as f64)),
            (
                "tokens",
                Json::Arr(o.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("text", Json::str(tokenizer::decode(&o.tokens))),
            ("finish", Json::str(finish)),
            ("ttft_ms", Json::num(o.ttft_s * 1e3)),
            ("tpot_ms", Json::num(o.tpot_s * 1e3)),
            ("prompt_len", Json::num(o.prompt_len as f64)),
            ("live_cache_tokens", Json::num(o.live_cache_tokens as f64)),
            ("preemptions", Json::num(o.preemptions as f64)),
            ("swaps", Json::num(o.swaps as f64)),
            (
                "prefix_hit_blocks",
                Json::num(o.cache_stats.prefix_hit_blocks as f64),
            ),
            ("cow_copies", Json::num(o.cache_stats.cow_copies as f64)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_text_request() {
        let r = WireRequest::parse(
            r#"{"id": 7, "text": "hi", "max_new_tokens": 4, "policy": "full"}"#,
        )
        .unwrap()
        .0;
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.policy, "full");
    }

    #[test]
    fn parse_prompt_ids() {
        let r = WireRequest::parse(r#"{"id": 1, "prompt": [1, 2, 3], "budget": 64}"#)
            .unwrap()
            .0;
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.budget, 64);
    }

    #[test]
    fn rejects_empty() {
        assert!(WireRequest::parse(r#"{"id": 1}"#).is_err());
        assert!(WireRequest::parse("garbage").is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        use crate::kvcache::CacheStats;
        let out = RequestOutput {
            id: 3,
            tokens: vec![104, 105],
            finish: FinishReason::MaxTokens,
            ttft_s: 0.01,
            tpot_s: 0.002,
            prompt_len: 5,
            live_cache_tokens: 64,
            preemptions: 2,
            swaps: 1,
            cache_stats: CacheStats {
                prefix_hit_blocks: 6,
                cow_copies: 2,
                ..CacheStats::default()
            },
        };
        let line = WireResponse(out).to_line();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(j.get("preemptions").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("swaps").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("prefix_hit_blocks").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("cow_copies").unwrap().as_usize(), Some(2));
    }
}
