//! NDJSON wire protocol: one JSON object per line, both directions.
//!
//! ## v2 (session-based, server-assigned ids)
//!
//! Inbound operations (`"op"` selects):
//!
//! ```json
//! {"op": "submit", "text": "...", "max_new_tokens": 32,
//!  "budget": 64, "policy": "keydiff", "priority": "high",
//!  "stop": [7, 9], "deadline_steps": 200, "stream": true}
//! {"op": "abort", "id": 3}
//! ```
//!
//! `prompt` (token-id array) may replace `text`; `policy`/`budget`
//! default to the SERVER's configured defaults; `priority` is
//! low|normal|high; `stream` defaults to the server's `--stream` flag.
//! The submit is acknowledged with `{"event": "accepted", "id": N}` —
//! the id is SERVER-assigned (raced submissions can never collide) and
//! is what `abort` takes. With `"stream": true` every lifecycle event
//! follows as its own line:
//!
//! ```json
//! {"event": "prefilled", "id": 3, "ttft_ms": 1.2}
//! {"event": "token", "id": 3, "tok": 104, "step": 0, "text": "h"}
//! {"event": "preempted", "id": 3, "swap": true}
//! {"event": "resumed", "id": 3}
//! {"event": "finished", "id": 3, "tokens": [...], ...}
//! ```
//!
//! With `"stream": false` only `accepted` and the legacy one-shot
//! response line (below) are written. An `abort` is answered with
//! `{"event": "aborted", "id": N, "ok": bool}`; aborting an unknown or
//! finished id is a clean no-op (`ok: false` + `error`), never a
//! protocol failure. An aborted request emits NO `finished` line —
//! its stream ends with the server's `aborted` notice. A streaming
//! connection reads its own stream until it ends, so the abort for an
//! in-flight streaming request must be sent on a SEPARATE connection
//! (or the client can simply disconnect: a closed — or stalled, see
//! `serve::EVENT_CHANNEL_CAP` — event sink cancels the request).
//!
//! ## v1 (legacy, caller-assigned ids)
//!
//! A line WITHOUT `"op"` is a v1 one-shot request:
//!   {"id": 1, "text": "..."} or {"id": 1, "prompt": [ids...]},
//!   optional: "max_new_tokens" (default 32), "budget", "policy",
//!             "eos" (token id), "stop" ([ids]), "priority",
//!             "deadline_steps"
//! Unset "policy"/"budget" inherit the SERVER's configured defaults —
//! same resolution as v2, so both protocol generations answer a given
//! prompt identically. Failures (e.g. an unknown policy) come back as a
//! response line carrying the caller's id with finish "error".
//! answered by one response line:
//!   {"id": 1, "tokens": [...], "text": "...",
//!    "finish": "length"|"eos"|"error"|"deadline",
//!    "ttft_ms": .., "tpot_ms": .., "live_cache_tokens": ..,
//!    "preemptions": .., "swaps": .., "retries": ..,
//!    "prefix_hit_blocks": .., "cow_copies": ..}

use anyhow::{Context, Result};

use crate::api::{RequestBuilder, SeqEvent};
use crate::scheduler::{FinishReason, Priority, Request, RequestOutput};
use crate::tokenizer;
use crate::util::json::Json;

fn parse_prompt(j: &Json) -> Result<Vec<u32>> {
    let prompt: Vec<u32> = if let Some(arr) = j.get("prompt").and_then(|v| v.as_arr()) {
        arr.iter()
            .map(|v| v.as_usize().map(|x| x as u32))
            .collect::<Option<Vec<u32>>>()
            .context("prompt must be an int array")?
    } else if let Some(text) = j.get("text").and_then(|v| v.as_str()) {
        tokenizer::encode(text)
    } else {
        anyhow::bail!("request needs 'prompt' (ids) or 'text'");
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    Ok(prompt)
}

fn parse_stop_set(j: &Json) -> Result<Vec<u32>> {
    match j.get("stop").and_then(|v| v.as_arr()) {
        Some(arr) => arr
            .iter()
            .map(|v| v.as_usize().map(|x| x as u32))
            .collect::<Option<Vec<u32>>>()
            .context("stop must be an int array"),
        None => Ok(Vec::new()),
    }
}

/// Legacy v1 request line (caller-assigned id, one-shot response).
#[derive(Debug, Clone)]
pub struct WireRequest(pub Request);

impl WireRequest {
    pub fn parse(line: &str) -> Result<WireRequest> {
        let j = Json::parse(line).context("bad request json")?;
        let id = j.get("id").and_then(|v| v.as_u64()).unwrap_or(0);
        let prompt = parse_prompt(&j)?;
        let mut req = Request::new(id, prompt, 32);
        if let Some(m) = j.get("max_new_tokens").and_then(|v| v.as_usize()) {
            req.max_new_tokens = m.max(1);
        }
        if let Some(b) = j.get("budget").and_then(|v| v.as_usize()) {
            req.budget = b;
        }
        if let Some(p) = j.get("policy").and_then(|v| v.as_str()) {
            req.policy = p.to_string();
        }
        if let Some(e) = j.get("eos").and_then(|v| v.as_usize()) {
            req.eos_token = Some(e as u32);
        }
        req.stop_tokens = parse_stop_set(&j)?;
        if let Some(p) = j.get("priority").and_then(|v| v.as_str()) {
            req.priority = Priority::parse(p)?;
        }
        if let Some(d) = j.get("deadline_steps").and_then(|v| v.as_u64()) {
            req.deadline_steps = Some(d);
        }
        Ok(WireRequest(req))
    }
}

trait JsonU64 {
    fn as_u64(&self) -> Option<u64>;
}

impl JsonU64 for Json {
    fn as_u64(&self) -> Option<u64> {
        self.as_i64().filter(|v| *v >= 0).map(|v| v as u64)
    }
}

/// Shared submission-field parsing for v1 and v2 lines. Every field is
/// optional: unset `policy`/`budget` inherit the SERVER's configured
/// defaults when the builder is resolved at submit, and the legacy
/// `"eos"` token folds into the stop-token set (identical finish
/// semantics), so v1 and v2 clients get the same answer for the same
/// prompt on the same server.
fn parse_builder(j: &Json, default_priority: Priority) -> Result<RequestBuilder> {
    let prompt = parse_prompt(j)?;
    let mut b = RequestBuilder::new(prompt).priority(default_priority);
    if let Some(m) = j.get("max_new_tokens").and_then(|v| v.as_usize()) {
        b = b.max_new_tokens(m);
    }
    if let Some(v) = j.get("budget").and_then(|v| v.as_usize()) {
        b = b.budget(v);
    }
    if let Some(p) = j.get("policy").and_then(|v| v.as_str()) {
        b = b.policy(p);
    }
    if let Some(p) = j.get("priority").and_then(|v| v.as_str()) {
        b = b.priority(Priority::parse(p)?);
    }
    if let Some(d) = j.get("deadline_steps").and_then(|v| v.as_u64()) {
        b = b.deadline_steps(d);
    }
    let mut stop = parse_stop_set(j)?;
    if let Some(e) = j.get("eos").and_then(|v| v.as_usize()) {
        stop.push(e as u32);
    }
    Ok(b.stop_tokens(stop))
}

/// One parsed inbound line of the v2 protocol.
#[derive(Debug, Clone)]
pub enum WireOp {
    /// v2 submission: the server assigns the id; `stream` selects
    /// per-event lines vs the one-shot response.
    Submit { builder: RequestBuilder, stream: bool },
    /// v2 cancellation by server-assigned id.
    Abort { id: u64 },
    /// v1 line (no `"op"` key): blocking one-shot with the caller's `id`
    /// echoed back. Parsed through the same optional-field builder as
    /// v2, so unset policy/budget inherit the server defaults too.
    Legacy { id: u64, builder: RequestBuilder },
}

impl WireOp {
    /// Parse one inbound line. `default_stream`/`default_priority` are
    /// the server's configured defaults for submits that leave them out.
    pub fn parse(line: &str, default_stream: bool, default_priority: Priority) -> Result<WireOp> {
        let j = Json::parse(line).context("bad request json")?;
        let Some(op) = j.get("op").and_then(|v| v.as_str()) else {
            let id = j.get("id").and_then(|v| v.as_u64()).unwrap_or(0);
            // one-shot: only the terminal output is ever read
            let builder = parse_builder(&j, default_priority)?.stream_events(false);
            return Ok(WireOp::Legacy { id, builder });
        };
        match op {
            "submit" => {
                let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(default_stream);
                // one-shot submits only read the terminal output: skip
                // materializing per-token events for them entirely
                let builder = parse_builder(&j, default_priority)?.stream_events(stream);
                Ok(WireOp::Submit { builder, stream })
            }
            "abort" => {
                let id = j
                    .get("id")
                    .and_then(|v| v.as_u64())
                    .context("abort needs a numeric 'id'")?;
                Ok(WireOp::Abort { id })
            }
            other => anyhow::bail!("unknown op {other:?} (want submit|abort)"),
        }
    }
}

fn finish_name(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "length",
        FinishReason::Error => "error",
        FinishReason::Deadline => "deadline",
    }
}

/// The full output field set shared by the v1 response line and the v2
/// `finished` event.
fn output_pairs(o: &RequestOutput) -> Vec<(&'static str, Json)> {
    vec![
        ("id", Json::num(o.id as f64)),
        (
            "tokens",
            Json::Arr(o.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("text", Json::str(tokenizer::decode(&o.tokens))),
        // the policy the request RAN under — for `--policy auto`
        // submissions, the autotuner's resolved choice
        ("policy", Json::str(&o.policy)),
        ("finish", Json::str(finish_name(o.finish))),
        ("ttft_ms", Json::num(o.ttft_s * 1e3)),
        ("tpot_ms", Json::num(o.tpot_s * 1e3)),
        ("prompt_len", Json::num(o.prompt_len as f64)),
        ("live_cache_tokens", Json::num(o.live_cache_tokens as f64)),
        ("preemptions", Json::num(o.preemptions as f64)),
        ("swaps", Json::num(o.swaps as f64)),
        ("retries", Json::num(o.retries as f64)),
        (
            "prefix_hit_blocks",
            Json::num(o.cache_stats.prefix_hit_blocks as f64),
        ),
        ("cow_copies", Json::num(o.cache_stats.cow_copies as f64)),
    ]
}

/// Legacy v1 one-shot response line.
#[derive(Debug, Clone)]
pub struct WireResponse(pub RequestOutput);

impl WireResponse {
    pub fn to_line(&self) -> String {
        Json::obj(output_pairs(&self.0)).to_string()
    }
}

/// Serialize one v2 event line for request `id`.
pub fn event_line(id: u64, ev: &SeqEvent) -> String {
    let mut pairs: Vec<(&'static str, Json)> = vec![("event", Json::str(ev.kind()))];
    match ev {
        SeqEvent::Prefilled { ttft_s } => {
            pairs.push(("id", Json::num(id as f64)));
            pairs.push(("ttft_ms", Json::num(ttft_s * 1e3)));
        }
        SeqEvent::Token { tok, step } => {
            pairs.push(("id", Json::num(id as f64)));
            pairs.push(("tok", Json::num(*tok as f64)));
            pairs.push(("step", Json::num(*step as f64)));
            pairs.push(("text", Json::str(tokenizer::decode(&[*tok]))));
        }
        SeqEvent::Preempted { swap } => {
            pairs.push(("id", Json::num(id as f64)));
            pairs.push(("swap", Json::Bool(*swap)));
        }
        SeqEvent::Resumed => {
            pairs.push(("id", Json::num(id as f64)));
        }
        SeqEvent::Finished(out) => {
            // the "id" lives in the shared field set
            pairs.extend(output_pairs(out));
        }
    }
    Json::obj(pairs).to_string()
}

/// v2 submit acknowledgement carrying the server-assigned id.
pub fn accepted_line(id: u64) -> String {
    Json::obj(vec![
        ("event", Json::str("accepted")),
        ("id", Json::num(id as f64)),
    ])
    .to_string()
}

/// v2 abort acknowledgement. `ok = false` (unknown/finished id, or the
/// stream ended first) is a clean no-op, not a protocol error.
pub fn aborted_line(id: u64, ok: bool) -> String {
    let mut pairs = vec![
        ("event", Json::str("aborted")),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(ok)),
    ];
    if !ok {
        pairs.push(("error", Json::str("unknown or finished id")));
    }
    Json::obj(pairs).to_string()
}

/// Error line (parse failures and other per-line faults).
pub fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_text_request() {
        let r = WireRequest::parse(
            r#"{"id": 7, "text": "hi", "max_new_tokens": 4, "policy": "full"}"#,
        )
        .unwrap()
        .0;
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.policy, "full");
        assert_eq!(r.priority, Priority::Normal);
    }

    #[test]
    fn parse_prompt_ids() {
        let r = WireRequest::parse(r#"{"id": 1, "prompt": [1, 2, 3], "budget": 64}"#)
            .unwrap()
            .0;
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.budget, 64);
    }

    #[test]
    fn rejects_empty() {
        assert!(WireRequest::parse(r#"{"id": 1}"#).is_err());
        assert!(WireRequest::parse("garbage").is_err());
    }

    #[test]
    fn v1_parses_priority_stop_and_deadline() {
        let r = WireRequest::parse(
            r#"{"id": 2, "prompt": [5], "priority": "high", "stop": [7, 9],
                "deadline_steps": 40}"#,
        )
        .unwrap()
        .0;
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.stop_tokens, vec![7, 9]);
        assert_eq!(r.deadline_steps, Some(40));
        assert!(WireRequest::parse(r#"{"prompt": [1], "priority": "zz"}"#).is_err());
    }

    #[test]
    fn v2_submit_parses_with_defaults_and_overrides() {
        let cfg = crate::scheduler::SchedConfig::default();
        let op = WireOp::parse(
            r#"{"op": "submit", "prompt": [1, 2], "stream": false,
                "policy": "keydiff", "budget": 64, "priority": "low",
                "max_new_tokens": 5, "stop": [3], "deadline_steps": 9}"#,
            true,
            Priority::Normal,
        )
        .unwrap();
        let WireOp::Submit { builder, stream } = op else { panic!("want submit") };
        assert!(!stream, "explicit stream:false wins over the default");
        let req = builder.build(crate::api::RequestId(11), &cfg);
        assert_eq!(req.id, 11);
        assert!(!req.stream_events, "one-shot submits skip event generation");
        assert_eq!(req.policy, "keydiff");
        assert_eq!(req.budget, 64);
        assert_eq!(req.priority, Priority::Low);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.stop_tokens, vec![3]);
        assert_eq!(req.deadline_steps, Some(9));

        // unset fields inherit the server defaults
        let op = WireOp::parse(
            r#"{"op": "submit", "text": "hi"}"#,
            true,
            Priority::High,
        )
        .unwrap();
        let WireOp::Submit { builder, stream } = op else { panic!("want submit") };
        assert!(stream, "server default stream mode applies");
        let req = builder.build(crate::api::RequestId(1), &cfg);
        assert_eq!(req.policy, cfg.default_policy);
        assert_eq!(req.budget, cfg.default_budget);
        assert_eq!(req.priority, Priority::High);
    }

    #[test]
    fn v2_abort_and_legacy_and_errors() {
        let cfg = crate::scheduler::SchedConfig {
            default_policy: "full".into(),
            default_budget: 2048,
            ..Default::default()
        };
        match WireOp::parse(r#"{"op": "abort", "id": 12}"#, false, Priority::Normal).unwrap() {
            WireOp::Abort { id } => assert_eq!(id, 12),
            other => panic!("want abort, got {other:?}"),
        }
        match WireOp::parse(r#"{"id": 4, "prompt": [1], "eos": 9}"#, false, Priority::Normal)
            .unwrap()
        {
            WireOp::Legacy { id, builder } => {
                assert_eq!(id, 4);
                let req = builder.build(crate::api::RequestId(1), &cfg);
                // v1 lines inherit the SERVER defaults for unset fields
                assert_eq!(req.policy, "full");
                assert_eq!(req.budget, 2048);
                assert_eq!(req.stop_tokens, vec![9], "eos folds into the stop set");
                assert!(!req.stream_events, "one-shot: no per-token events");
            }
            other => panic!("want legacy, got {other:?}"),
        }
        // v2 honors "eos" too (migrating v1 clients keep their stop token)
        match WireOp::parse(
            r#"{"op": "submit", "prompt": [1], "eos": 7, "stop": [5]}"#,
            true,
            Priority::Normal,
        )
        .unwrap()
        {
            WireOp::Submit { builder, .. } => {
                let req = builder.build(crate::api::RequestId(2), &cfg);
                assert_eq!(req.stop_tokens, vec![5, 7]);
            }
            other => panic!("want submit, got {other:?}"),
        }
        assert!(WireOp::parse(r#"{"op": "abort"}"#, false, Priority::Normal).is_err());
        assert!(WireOp::parse(r#"{"op": "noop"}"#, false, Priority::Normal).is_err());
    }

    #[test]
    fn event_lines_roundtrip_as_json() {
        let l = event_line(3, &SeqEvent::Prefilled { ttft_s: 0.001 });
        let j = Json::parse(&l).unwrap();
        assert_eq!(j.get("event").unwrap().as_str(), Some("prefilled"));
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert!((j.get("ttft_ms").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);

        let l = event_line(3, &SeqEvent::Token { tok: 104, step: 2 });
        let j = Json::parse(&l).unwrap();
        assert_eq!(j.get("event").unwrap().as_str(), Some("token"));
        assert_eq!(j.get("tok").unwrap().as_usize(), Some(104));
        assert_eq!(j.get("step").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("text").unwrap().as_str(), Some("h"));

        let l = event_line(3, &SeqEvent::Preempted { swap: true });
        let j = Json::parse(&l).unwrap();
        assert_eq!(j.get("swap").unwrap().as_bool(), Some(true));

        let j = Json::parse(&accepted_line(9)).unwrap();
        assert_eq!(j.get("event").unwrap().as_str(), Some("accepted"));
        assert_eq!(j.get("id").unwrap().as_usize(), Some(9));

        let j = Json::parse(&aborted_line(9, false)).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.get("error").is_some());
        let j = Json::parse(&aborted_line(9, true)).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert!(j.get("error").is_none());
    }

    #[test]
    fn response_roundtrips_as_json() {
        use crate::kvcache::CacheStats;
        let out = RequestOutput {
            id: 3,
            tokens: vec![104, 105],
            policy: "self_attn".to_string(),
            finish: FinishReason::MaxTokens,
            ttft_s: 0.01,
            tpot_s: 0.002,
            prompt_len: 5,
            live_cache_tokens: 64,
            preemptions: 2,
            swaps: 1,
            retries: 3,
            cache_stats: CacheStats {
                prefix_hit_blocks: 6,
                cow_copies: 2,
                ..CacheStats::default()
            },
        };
        // the v2 finished event carries the same field set as the v1 line
        let fin = event_line(3, &SeqEvent::Finished(out.clone()));
        let jf = Json::parse(&fin).unwrap();
        assert_eq!(jf.get("event").unwrap().as_str(), Some("finished"));
        let line = WireResponse(out).to_line();
        let j = Json::parse(&line).unwrap();
        for key in [
            "id", "tokens", "text", "policy", "finish", "ttft_ms", "tpot_ms", "prompt_len",
            "live_cache_tokens", "preemptions", "swaps", "retries", "prefix_hit_blocks",
            "cow_copies",
        ] {
            assert_eq!(j.get(key), jf.get(key), "field {key} diverged between v1 and v2");
        }
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("policy").unwrap().as_str(), Some("self_attn"));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(j.get("preemptions").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("swaps").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("retries").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("prefix_hit_blocks").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("cow_copies").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn deadline_finish_serializes() {
        let out = RequestOutput {
            id: 1,
            tokens: vec![],
            policy: "paged".to_string(),
            finish: FinishReason::Deadline,
            ttft_s: 0.0,
            tpot_s: 0.0,
            prompt_len: 1,
            live_cache_tokens: 0,
            preemptions: 0,
            swaps: 0,
            retries: 0,
            cache_stats: Default::default(),
        };
        let j = Json::parse(&WireResponse(out).to_line()).unwrap();
        assert_eq!(j.get("finish").unwrap().as_str(), Some("deadline"));
    }
}
