//! NDJSON TCP serving frontend.
//!
//! The wire protocol, the generic engine loop and the TCP accept loop are
//! pure host code and always built — `spawn_sim_engine` serves the
//! deterministic sim backend with no PJRT at all (tier-1 tested end to
//! end over real TCP in `tests/serve_v2.rs`). Only the PJRT engine
//! spawner (`serve::spawn_engine`) needs the `xla` feature: PJRT handles
//! are not `Send`, so that engine + scheduler live on one dedicated
//! thread; connection threads parse requests and exchange them with the
//! loop over std mpsc channels — the same process split vLLM makes
//! between its API server and the worker.

pub mod protocol;
pub mod serve;

pub use protocol::{WireOp, WireRequest, WireResponse};
pub use serve::{
    serve_forever, serve_until, spawn_sim_engine, spawn_sim_engine_faulty, EngineHandle,
    EngineMsg, ServeOpts, ShutdownFlag,
};
