//! JSON-lines TCP serving frontend.
//!
//! PJRT handles are not `Send`, so the engine + scheduler live on one
//! dedicated thread (the "engine loop"); connection threads parse requests
//! and exchange them with the loop over std mpsc channels — the same
//! process split vLLM makes between its API server and the worker.

//! The wire protocol is pure host code and always built; the engine loop
//! and TCP frontend drive the PJRT scheduler and are gated behind the
//! `xla` feature.

pub mod protocol;
#[cfg(feature = "xla")]
pub mod serve;

pub use protocol::{WireRequest, WireResponse};
#[cfg(feature = "xla")]
pub use serve::{serve_forever, EngineHandle};
