//! JSON-lines TCP serving frontend.
//!
//! PJRT handles are not `Send`, so the engine + scheduler live on one
//! dedicated thread (the "engine loop"); connection threads parse requests
//! and exchange them with the loop over std mpsc channels — the same
//! process split vLLM makes between its API server and the worker.

pub mod protocol;
pub mod serve;

pub use protocol::{WireRequest, WireResponse};
pub use serve::{serve_forever, EngineHandle};
