//! `paged-eviction` — serving CLI.
//!
//! Subcommands:
//!   serve     run the JSON-lines TCP server
//!   generate  one-shot generation (text or token ids)
//!   info      artifact/manifest summary
//!   simulate  one accuracy-simulator sweep row
//!   schedule  batched-scheduler demo on the deterministic sim backend
//!             (shared arena, preemption under pressure; no PJRT needed)
//!
//! Examples:
//!   paged-eviction serve --model sim-1b --port 7071
//!   paged-eviction generate --text "hello" --max-new-tokens 16
//!   paged-eviction simulate --dataset hotpotqa --policy paged --budget 1024
//!   paged-eviction schedule --requests 16 --arena-blocks 64 --gen 48

use anyhow::Result;

use paged_eviction::eviction::make_policy;
use paged_eviction::sim;
use paged_eviction::util::args::ArgSpec;

fn main() {
    env_logger_init();
    let cmd = std::env::args().nth(1).unwrap_or_default();
    let r = match cmd.as_str() {
        "serve" => cmd_serve(),
        "generate" => cmd_generate(),
        "info" => cmd_info(),
        "simulate" => cmd_simulate(),
        "schedule" => cmd_schedule(),
        _ => {
            eprintln!(
                "usage: paged-eviction <serve|generate|info|simulate|schedule> [options]\n\
                 run `paged-eviction <cmd> --help` for details"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_init() {
    // minimal logger: RUST_LOG=debug|info|warn controls verbosity
    struct L(log::Level);
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= self.0
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::Level::Debug,
        Ok("warn") => log::Level::Warn,
        Ok("trace") => log::Level::Trace,
        _ => log::Level::Info,
    };
    let _ = log::set_boxed_logger(Box::new(L(level)));
    log::set_max_level(level.to_level_filter());
}

#[cfg(feature = "xla")]
fn artifacts_flag(spec: ArgSpec) -> ArgSpec {
    spec.opt("artifacts", "artifacts", "artifact directory (make artifacts)")
}

/// Parse an `--prefix-cache on|off` style switch.
fn parse_on_off(flag: &str, s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => anyhow::bail!("--{flag} wants on|off (got {s:?})"),
    }
}

/// FNV-style digest over the generated token streams (id order) — lets
/// scripts assert two runs produced bit-identical outputs (e.g. the CI
/// smoke comparing `--prefix-cache on` vs `off`).
fn output_digest(outs: &[paged_eviction::scheduler::RequestOutput]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for o in outs {
        h = (h ^ o.id).wrapping_mul(0x0000_0100_0000_01b3);
        for &t in &o.tokens {
            h = (h ^ (u64::from(t) + 1)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Parse a `--watermarks low,high` value (fractions of the arena).
fn parse_watermarks(s: &str) -> Result<(f64, f64)> {
    let (lo, hi) = s
        .split_once(',')
        .ok_or_else(|| anyhow::anyhow!("--watermarks wants low,high (e.g. 0.85,0.95)"))?;
    let low: f64 = lo.trim().parse().map_err(|_| anyhow::anyhow!("bad low watermark {lo:?}"))?;
    let high: f64 =
        hi.trim().parse().map_err(|_| anyhow::anyhow!("bad high watermark {hi:?}"))?;
    anyhow::ensure!(
        low > 0.0 && low <= high && high <= 1.0,
        "watermarks must satisfy 0 < low <= high <= 1 (got {low}, {high})"
    );
    Ok((low, high))
}

/// The PJRT-backed subcommands need the `xla` feature (real bindings).
#[cfg(not(feature = "xla"))]
fn cmd_serve() -> Result<()> {
    no_xla("serve")
}

#[cfg(not(feature = "xla"))]
fn cmd_generate() -> Result<()> {
    no_xla("generate")
}

#[cfg(not(feature = "xla"))]
fn cmd_info() -> Result<()> {
    no_xla("info")
}

#[cfg(not(feature = "xla"))]
fn no_xla(cmd: &str) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` needs the PJRT runtime: rebuild with `cargo build --features xla` \
         (and link the real xla-rs bindings — see rust/vendor/README.md). \
         The `simulate` subcommand works without it."
    )
}

#[cfg(feature = "xla")]
fn cmd_serve() -> Result<()> {
    use std::sync::{Arc, Mutex};

    use paged_eviction::scheduler::SchedConfig;
    use paged_eviction::server::serve::{serve_forever, spawn_engine};

    let args = artifacts_flag(
        ArgSpec::new("paged-eviction serve", "JSON-lines TCP serving frontend")
            .opt("model", "sim-1b", "model name from the manifest")
            .opt("port", "7071", "TCP port")
            .opt("page-size", "16", "KV page size (8|16|32)")
            .opt("max-concurrency", "8", "max sequences decoded concurrently")
            .opt("max-live-blocks", "4096", "global KV block capacity")
            .opt("swap-bytes", "67108864", "host swap pool byte cap \
                 (0 = recompute-only preemption)")
            .opt("watermarks", "0.85,0.95", "admission/preemption watermarks \
                 as low,high fractions of the arena")
            .opt("prefix-cache", "on", "share identical prompt-prefix blocks \
                 across requests by refcount (on|off)")
            .opt("config", "", "TOML config file ([server]/[cache] sections \
                 override the flags; see docs in util::toml)"),
    )
    .parse_or_exit(2);
    let (watermark_low, watermark_high) = parse_watermarks(args.get("watermarks"))?;
    let mut cfg = SchedConfig {
        model: args.get("model").to_string(),
        page_size: args.get_usize("page-size"),
        max_concurrency: args.get_usize("max-concurrency"),
        max_live_blocks: args.get_usize("max-live-blocks"),
        watermark_low,
        watermark_high,
        swap_bytes: args.get_usize("swap-bytes"),
        prefix_cache: parse_on_off("prefix-cache", args.get("prefix-cache"))?,
    };
    if !args.get("config").is_empty() {
        use paged_eviction::util::toml;
        let text = std::fs::read_to_string(args.get("config"))?;
        let doc = toml::parse(&text)?;
        if let Some(v) = toml::get(&doc, "server", "model").and_then(|v| v.as_str()) {
            cfg.model = v.to_string();
        }
        if let Some(v) = toml::get(&doc, "server", "max_concurrency").and_then(|v| v.as_usize()) {
            cfg.max_concurrency = v;
        }
        if let Some(v) = toml::get(&doc, "cache", "page_size").and_then(|v| v.as_usize()) {
            cfg.page_size = v;
        }
        if let Some(v) = toml::get(&doc, "cache", "max_live_blocks").and_then(|v| v.as_usize()) {
            cfg.max_live_blocks = v;
        }
    }
    let (handle, _join) = spawn_engine(args.get("artifacts").into(), cfg)?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", args.get_usize("port") as u16))?;
    println!("serving on {}", listener.local_addr()?);
    serve_forever(listener, handle, Arc::new(Mutex::new(0)))
}

#[cfg(feature = "xla")]
fn cmd_generate() -> Result<()> {
    use paged_eviction::runtime::model_runner::argmax;
    use paged_eviction::runtime::{Engine, ModelRunner};
    use paged_eviction::tokenizer;

    let args = artifacts_flag(
        ArgSpec::new("paged-eviction generate", "one-shot generation")
            .opt("model", "sim-1b", "model name")
            .opt("text", "", "prompt text (byte tokenizer)")
            .opt("prompt", "", "comma-separated token ids (overrides --text)")
            .opt("max-new-tokens", "16", "generation length")
            .opt("budget", "1024", "KV cache budget (tokens)")
            .opt("policy", "paged", "eviction policy")
            .opt("page-size", "16", "KV page size"),
    )
    .parse_or_exit(2);
    let prompt: Vec<u32> = if !args.get("prompt").is_empty() {
        args.get_usize_list("prompt").iter().map(|&x| x as u32).collect()
    } else if !args.get("text").is_empty() {
        tokenizer::encode(args.get("text"))
    } else {
        anyhow::bail!("need --text or --prompt");
    };
    let engine = Engine::new(args.get("artifacts"))?;
    let runner = ModelRunner::new(&engine, args.get("model"), args.get_usize("page-size"))?;
    let policy = make_policy(args.get("policy"))?;
    let t0 = std::time::Instant::now();
    let (mut seq, logits) = runner.prefill(&prompt, args.get_usize("budget"), policy)?;
    let mut tok = argmax(&logits);
    let mut out = Vec::new();
    for _ in 0..args.get_usize("max-new-tokens") {
        out.push(tok);
        let step = runner.decode_step(&mut seq, tok)?;
        tok = argmax(&step.logits);
    }
    println!("tokens: {out:?}");
    println!("text:   {:?}", tokenizer::decode(&out));
    println!(
        "cache:  live={} blocks={} partial={} evicted_blocks={} in {:.1} ms",
        seq.cache.live_tokens(),
        seq.cache.n_blocks(),
        seq.cache.partial_blocks(),
        seq.cache.stats.blocks_evicted,
        t0.elapsed().as_secs_f64() * 1e3,
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_info() -> Result<()> {
    use paged_eviction::runtime::Engine;

    let args = artifacts_flag(ArgSpec::new("paged-eviction info", "artifact summary"))
        .parse_or_exit(2);
    let engine = Engine::new(args.get("artifacts"))?;
    println!("platform: {}", engine.platform());
    println!("kernel impl: {}", engine.manifest.kernel_impl);
    for (name, m) in &engine.manifest.models {
        println!(
            "model {name}: {}L d{} {}h/{}kv dh{} ff{} vocab {} ({} params, weights: {})",
            m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_head, m.d_ff,
            m.vocab_size, m.n_params, m.weights_src,
        );
    }
    println!("graphs: {}", engine.manifest.graphs.len());
    for g in &engine.manifest.graphs {
        println!("  {}", g.name);
    }
    Ok(())
}

/// Batched-scheduler demo: synthetic requests through the full admission /
/// batched-decode / preemption pipeline on the deterministic sim backend.
fn cmd_schedule() -> Result<()> {
    use paged_eviction::scheduler::{Request, SchedConfig, Scheduler};
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::recall;

    let args = ArgSpec::new(
        "paged-eviction schedule",
        "batched continuous-batching rounds over a shared block arena (sim backend)",
    )
    .opt("requests", "16", "synthetic requests to submit")
    .opt("prompt-len", "96", "prompt tokens per request")
    .opt("gen", "48", "output tokens per request")
    .opt("budget", "64", "KV cache budget (tokens)")
    .opt("policy", "paged", "eviction policy")
    .opt("page-size", "8", "KV page size")
    .opt("concurrency", "4", "max concurrent sequences")
    .opt("arena-blocks", "96", "shared arena capacity (blocks)")
    .opt("swap-bytes", "67108864", "host swap pool byte cap \
         (0 = recompute-only preemption)")
    .opt("watermarks", "0.85,0.95", "admission/preemption watermarks \
         as low,high fractions of the arena")
    .opt("prefix-cache", "on", "share identical prompt-prefix blocks \
         across requests by refcount (on|off)")
    .opt("shared-prefix", "0", "tokens of common prompt prefix across all \
         requests (exercises the prefix cache, e.g. a shared system prompt)")
    .opt("seed", "7", "prompt RNG seed")
    .parse_or_exit(2);

    let (watermark_low, watermark_high) = parse_watermarks(args.get("watermarks"))?;
    let cfg = SchedConfig {
        model: "sim".into(),
        page_size: args.get_usize("page-size"),
        max_concurrency: args.get_usize("concurrency"),
        max_live_blocks: args.get_usize("arena-blocks"),
        watermark_low,
        watermark_high,
        swap_bytes: args.get_usize("swap-bytes"),
        prefix_cache: parse_on_off("prefix-cache", args.get("prefix-cache"))?,
    };
    let mut sched = Scheduler::new_sim(cfg);
    let mut rng = Pcg32::new(args.get_u64("seed"));
    let prompt_len = args.get_usize("prompt-len");
    // clamped so the per-request recall tail keeps make_prompt's contract
    // (>= 8 tokens, even length for an even --prompt-len)
    let shared_len = args.get_usize("shared-prefix").min(prompt_len.saturating_sub(8)) & !1;
    // the shared system-prompt stand-in: one common prefix, distinct tails
    let shared: Vec<u32> = (0..shared_len).map(|_| rng.below(200)).collect();
    for i in 0..args.get_usize("requests") {
        let p = recall::make_prompt(&mut rng, prompt_len - shared_len, 0.4);
        let mut prompt = shared.clone();
        prompt.extend(p.tokens);
        let mut req = Request::new(i as u64 + 1, prompt, args.get_usize("gen"));
        req.budget = args.get_usize("budget");
        req.policy = args.get("policy").to_string();
        sched.submit(req);
    }
    let mut outs = sched.run_to_completion()?;
    outs.sort_by_key(|o| o.id);
    println!(
        "{} requests done: {:.0} tok/s, {} preemptions ({} swapped out, {} restored, \
         {} dropped), peak arena {} / {} blocks",
        outs.len(),
        sched.throughput_tok_s(),
        sched.preemptions,
        sched.swap_outs,
        sched.swap_restores,
        sched.swap_pool().dropped(),
        sched.arena().stats().peak_used,
        sched.arena().capacity(),
    );
    println!(
        "prefix cache: {} prefix-hit blocks, {} cow copies, output digest {:016x}",
        sched.prefix_hit_blocks,
        sched.cow_copies,
        output_digest(&outs),
    );
    for o in &outs {
        println!(
            "  req {:>3}: {:>3} tokens, finish {:?}, ttft {:.2} ms, preempted {}x \
             (swap-restored {}x)",
            o.id,
            o.tokens.len(),
            o.finish,
            o.ttft_s * 1e3,
            o.preemptions,
            o.swaps,
        );
    }
    Ok(())
}

fn cmd_simulate() -> Result<()> {
    let args = ArgSpec::new(
        "paged-eviction simulate",
        "accuracy-simulator sweep row (see DESIGN.md §4 for what this models)",
    )
    .opt("dataset", "govreport", "govreport|multinews|hotpotqa|multifieldqa|qasper")
    .opt("policy", "paged", "eviction policy")
    .opt("budget", "1024", "cache budget tokens")
    .opt("page-size", "16", "page size")
    .opt("episodes", "32", "episodes to average")
    .opt("seed", "0", "base seed")
    .parse_or_exit(2);
    let d = sim::datasets::dataset(args.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let cfg = sim::SimConfig {
        budget: args.get_usize("budget"),
        page_size: args.get_usize("page-size"),
        seed: args.get_u64("seed"),
        ..Default::default()
    };
    let p = make_policy(args.get("policy"))?;
    let r = sim::attention_sim::simulate_mean(d, p.as_ref(), &cfg, args.get_usize("episodes"));
    println!(
        "{} {} budget={} -> score {:.2} (coverage {:.3}, needles {:.2}, partial_blocks {})",
        args.get("dataset"),
        args.get("policy"),
        cfg.budget,
        r.score,
        r.coverage,
        r.needles_retained,
        r.partial_blocks,
    );
    Ok(())
}
