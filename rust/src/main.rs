//! `paged-eviction` — serving CLI.
//!
//! Subcommands:
//!   serve     run the NDJSON TCP server (v2 streaming protocol;
//!             `--backend sim` needs no PJRT, `--backend pjrt` the real
//!             runtime)
//!   generate  one-shot generation (text or token ids)
//!   info      artifact/manifest summary
//!   simulate  one accuracy-simulator sweep row
//!   schedule  batched-scheduler demo on the deterministic sim backend
//!             (shared arena, preemption under pressure, streaming
//!             events, mid-run aborts; no PJRT needed)
//!   slo       replay named SLO scenarios (seeded multi-tenant traffic)
//!             through the multi-worker engine and report tail latency,
//!             goodput and per-scenario digests (`BENCH_slo.json`)
//!
//! Examples:
//!   paged-eviction serve --port 7071 --stream on
//!   paged-eviction generate --text "hello" --max-new-tokens 16
//!   paged-eviction simulate --dataset hotpotqa --policy paged --budget 1024
//!   paged-eviction schedule --requests 16 --arena-blocks 64 --gen 48
//!   paged-eviction schedule --stream on --abort 3@4
//!   paged-eviction schedule --trace requests.trace
//!   paged-eviction schedule --policy auto --requests 16 --arena-blocks 64
//!   paged-eviction slo --scenario bursty-chat,longbench-replay --workers 1,4
//!   paged-eviction slo --scenario diurnal-mixed --policy auto --workers 1,4

use anyhow::Result;

use paged_eviction::eviction::{make_policy, validate_request_policy};
use paged_eviction::sim;
use paged_eviction::util::args::ArgSpec;

fn main() {
    env_logger_init();
    let cmd = std::env::args().nth(1).unwrap_or_default();
    let r = match cmd.as_str() {
        "serve" => cmd_serve(),
        "generate" => cmd_generate(),
        "info" => cmd_info(),
        "simulate" => cmd_simulate(),
        "schedule" => cmd_schedule(),
        "slo" => cmd_slo(),
        _ => {
            eprintln!(
                "usage: paged-eviction <serve|generate|info|simulate|schedule|slo> [options]\n\
                 run `paged-eviction <cmd> --help` for details"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_init() {
    // minimal logger: RUST_LOG=debug|info|warn controls verbosity
    struct L(log::Level);
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= self.0
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::Level::Debug,
        Ok("warn") => log::Level::Warn,
        Ok("trace") => log::Level::Trace,
        _ => log::Level::Info,
    };
    let _ = log::set_boxed_logger(Box::new(L(level)));
    log::set_max_level(level.to_level_filter());
}

#[cfg(feature = "xla")]
fn artifacts_flag(spec: ArgSpec) -> ArgSpec {
    spec.opt("artifacts", "artifacts", "artifact directory (make artifacts)")
}

/// Parse an `--prefix-cache on|off` style switch.
fn parse_on_off(flag: &str, s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => anyhow::bail!("--{flag} wants on|off (got {s:?})"),
    }
}

/// FNV-style digest over the generated token streams (id order) — lets
/// scripts assert two runs produced bit-identical outputs (e.g. the CI
/// smoke comparing `--prefix-cache on` vs `off`, or survivors of a
/// mid-run abort vs an abort-free run).
fn output_digest(outs: &[paged_eviction::scheduler::RequestOutput]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for o in outs {
        h = (h ^ o.id).wrapping_mul(0x0000_0100_0000_01b3);
        for &t in &o.tokens {
            h = (h ^ (u64::from(t) + 1)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Parse a `--watermarks low,high` value (fractions of the arena).
fn parse_watermarks(s: &str) -> Result<(f64, f64)> {
    let (lo, hi) = s
        .split_once(',')
        .ok_or_else(|| anyhow::anyhow!("--watermarks wants low,high (e.g. 0.85,0.95)"))?;
    let low: f64 = lo.trim().parse().map_err(|_| anyhow::anyhow!("bad low watermark {lo:?}"))?;
    let high: f64 =
        hi.trim().parse().map_err(|_| anyhow::anyhow!("bad high watermark {hi:?}"))?;
    anyhow::ensure!(
        low > 0.0 && low <= high && high <= 1.0,
        "watermarks must satisfy 0 < low <= high <= 1 (got {low}, {high})"
    );
    Ok((low, high))
}

/// Parse an `--abort "id@step,id@step"` spec.
fn parse_aborts(s: &str) -> Result<Vec<(u64, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let (id, step) = part
            .trim()
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("--abort wants id@step (got {part:?})"))?;
        out.push((
            id.parse().map_err(|_| anyhow::anyhow!("bad abort id {id:?}"))?,
            step.parse().map_err(|_| anyhow::anyhow!("bad abort step {step:?}"))?,
        ));
    }
    Ok(out)
}

/// The PJRT-backed subcommands need the `xla` feature (real bindings).
#[cfg(not(feature = "xla"))]
fn cmd_generate() -> Result<()> {
    no_xla("generate")
}

#[cfg(not(feature = "xla"))]
fn cmd_info() -> Result<()> {
    no_xla("info")
}

#[cfg(not(feature = "xla"))]
fn no_xla(cmd: &str) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` needs the PJRT runtime: rebuild with `cargo build --features xla` \
         (and link the real xla-rs bindings — see rust/vendor/README.md). \
         The `serve --backend sim`, `simulate` and `schedule` subcommands \
         work without it."
    )
}

#[cfg(feature = "xla")]
fn spawn_pjrt(
    artifacts: std::path::PathBuf,
    cfg: paged_eviction::scheduler::SchedConfig,
) -> Result<(paged_eviction::server::EngineHandle, std::thread::JoinHandle<()>)> {
    paged_eviction::server::serve::spawn_engine(artifacts, cfg)
}

#[cfg(not(feature = "xla"))]
fn spawn_pjrt(
    _artifacts: std::path::PathBuf,
    _cfg: paged_eviction::scheduler::SchedConfig,
) -> Result<(paged_eviction::server::EngineHandle, std::thread::JoinHandle<()>)> {
    anyhow::bail!(
        "`--backend pjrt` needs the PJRT runtime: rebuild with \
         `cargo build --features xla`. `--backend sim` serves without it."
    )
}

fn cmd_serve() -> Result<()> {
    use paged_eviction::scheduler::{default_workers, Priority, SchedConfig};
    use paged_eviction::server::serve::{serve_forever, spawn_sim_engine, ServeOpts};

    let args = ArgSpec::new(
        "paged-eviction serve",
        "NDJSON TCP serving frontend (v2 streaming protocol + v1 one-shot compat)",
    )
    .opt("backend", "sim", "decode backend: sim (always available) or \
         pjrt (needs --features xla and artifacts)")
    .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
    .opt("model", "sim-1b", "model name from the manifest")
    .opt("port", "7071", "TCP port")
    .opt("page-size", "16", "KV page size (8|16|32)")
    .opt("max-concurrency", "8", "max sequences decoded concurrently")
    .opt("workers", &default_workers().to_string(), "scheduler worker \
         threads sharing one arena/swap pool (sim backend; 1 = classic \
         single-threaded loop)")
    .opt("max-live-blocks", "4096", "global KV block capacity")
    .opt("swap-bytes", "67108864", "host swap pool byte cap \
         (0 = recompute-only preemption)")
    .opt("watermarks", "0.85,0.95", "admission/preemption watermarks \
         as low,high fractions of the arena")
    .opt("prefix-cache", "on", "share identical prompt-prefix blocks \
         across requests by refcount (on|off)")
    .opt("policy", "paged", "server-default eviction policy, or \"auto\" \
         for the per-request autotuner (requests override per submit)")
    .opt("budget", "1024", "server-default KV budget in tokens \
         (requests override per submit)")
    .opt("priority", "normal", "priority for requests that do not name \
         one (low|normal|high)")
    .opt("stream", "off", "default stream mode for v2 submits without \
         an explicit \"stream\" field (on|off)")
    .opt("read-timeout-ms", "0", "per-connection read timeout in ms while \
         waiting for a request line; 0 = wait forever")
    .opt("max-line-bytes", "1048576", "longest inbound request line the \
         server will buffer before shedding the connection")
    .opt("max-conns", "1024", "concurrent connection cap; extra \
         connections are shed at accept with an error line")
    .opt("faults", "", "deterministic fault-injection spec for the sim \
         backend (see runtime::faults), e.g. transient@r2s4,seed=42")
    .opt("config", "", "TOML config file ([server]/[cache] sections \
         override the flags; see docs in util::toml)")
    .parse_or_exit(2);
    let (watermark_low, watermark_high) = parse_watermarks(args.get("watermarks"))?;
    let mut cfg = SchedConfig {
        model: args.get("model").to_string(),
        page_size: args.get_usize("page-size"),
        max_concurrency: args.get_usize("max-concurrency"),
        max_live_blocks: args.get_usize("max-live-blocks"),
        watermark_low,
        watermark_high,
        swap_bytes: args.get_usize("swap-bytes"),
        prefix_cache: parse_on_off("prefix-cache", args.get("prefix-cache"))?,
        default_policy: args.get("policy").to_string(),
        default_budget: args.get_usize("budget"),
        workers: args.get_usize("workers").max(1),
        ..SchedConfig::default()
    };
    // fail fast on a bad default ("auto" is valid: the scheduler resolves
    // the autotuner sentinel per request at submit)
    validate_request_policy(&cfg.default_policy)?;
    if !args.get("config").is_empty() {
        use paged_eviction::util::toml;
        let text = std::fs::read_to_string(args.get("config"))?;
        let doc = toml::parse(&text)?;
        if let Some(v) = toml::get(&doc, "server", "model").and_then(|v| v.as_str()) {
            cfg.model = v.to_string();
        }
        if let Some(v) = toml::get(&doc, "server", "max_concurrency").and_then(|v| v.as_usize()) {
            cfg.max_concurrency = v;
        }
        if let Some(v) = toml::get(&doc, "cache", "page_size").and_then(|v| v.as_usize()) {
            cfg.page_size = v;
        }
        if let Some(v) = toml::get(&doc, "cache", "max_live_blocks").and_then(|v| v.as_usize()) {
            cfg.max_live_blocks = v;
        }
    }
    let timeout_ms = args.get_u64("read-timeout-ms");
    let opts = ServeOpts {
        default_stream: parse_on_off("stream", args.get("stream"))?,
        default_priority: Priority::parse(args.get("priority"))?,
        max_line_bytes: args.get_usize("max-line-bytes"),
        read_timeout: (timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(timeout_ms)),
        max_connections: args.get_usize("max-conns"),
    };
    if args.get("backend") == "pjrt" && cfg.workers > 1 {
        // PJRT handles are not Send: that engine lives on one thread
        log::warn!("--backend pjrt is single-threaded; ignoring --workers {}", cfg.workers);
        cfg.workers = 1;
    }
    let faults = args.get("faults");
    let (handle, _join) = match (args.get("backend"), faults.is_empty()) {
        ("sim", true) => spawn_sim_engine(cfg)?,
        ("sim", false) => {
            let plan = paged_eviction::runtime::FaultPlan::parse(faults)?;
            paged_eviction::server::serve::spawn_sim_engine_faulty(cfg, plan)?
        }
        ("pjrt", true) => spawn_pjrt(args.get("artifacts").into(), cfg)?,
        ("pjrt", false) => anyhow::bail!("--faults needs --backend sim"),
        (other, _) => anyhow::bail!("unknown backend {other:?} (want sim|pjrt)"),
    };
    let listener = std::net::TcpListener::bind(("127.0.0.1", args.get_usize("port") as u16))?;
    println!("serving on {} ({} backend)", listener.local_addr()?, args.get("backend"));
    serve_forever(listener, handle, opts)
}

#[cfg(feature = "xla")]
fn cmd_generate() -> Result<()> {
    use paged_eviction::runtime::model_runner::argmax;
    use paged_eviction::runtime::{Engine, ModelRunner};
    use paged_eviction::tokenizer;

    let args = artifacts_flag(
        ArgSpec::new("paged-eviction generate", "one-shot generation")
            .opt("model", "sim-1b", "model name")
            .opt("text", "", "prompt text (byte tokenizer)")
            .opt("prompt", "", "comma-separated token ids (overrides --text)")
            .opt("max-new-tokens", "16", "generation length")
            .opt("budget", "1024", "KV cache budget (tokens)")
            .opt("policy", "paged", "eviction policy")
            .opt("page-size", "16", "KV page size"),
    )
    .parse_or_exit(2);
    let prompt: Vec<u32> = if !args.get("prompt").is_empty() {
        args.get_usize_list("prompt").iter().map(|&x| x as u32).collect()
    } else if !args.get("text").is_empty() {
        tokenizer::encode(args.get("text"))
    } else {
        anyhow::bail!("need --text or --prompt");
    };
    let engine = Engine::new(args.get("artifacts"))?;
    let runner = ModelRunner::new(&engine, args.get("model"), args.get_usize("page-size"))?;
    let policy = make_policy(args.get("policy"))?;
    let t0 = std::time::Instant::now();
    let (mut seq, logits) = runner.prefill(&prompt, args.get_usize("budget"), policy)?;
    let mut tok = argmax(&logits);
    let mut out = Vec::new();
    for _ in 0..args.get_usize("max-new-tokens") {
        out.push(tok);
        let step = runner.decode_step(&mut seq, tok)?;
        tok = argmax(&step.logits);
    }
    println!("tokens: {out:?}");
    println!("text:   {:?}", tokenizer::decode(&out));
    println!(
        "cache:  live={} blocks={} partial={} evicted_blocks={} in {:.1} ms",
        seq.cache.live_tokens(),
        seq.cache.n_blocks(),
        seq.cache.partial_blocks(),
        seq.cache.stats.blocks_evicted,
        t0.elapsed().as_secs_f64() * 1e3,
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_info() -> Result<()> {
    use paged_eviction::runtime::Engine;

    let args = artifacts_flag(ArgSpec::new("paged-eviction info", "artifact summary"))
        .parse_or_exit(2);
    let engine = Engine::new(args.get("artifacts"))?;
    println!("platform: {}", engine.platform());
    println!("kernel impl: {}", engine.manifest.kernel_impl);
    for (name, m) in &engine.manifest.models {
        println!(
            "model {name}: {}L d{} {}h/{}kv dh{} ff{} vocab {} ({} params, weights: {})",
            m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_head, m.d_ff,
            m.vocab_size, m.n_params, m.weights_src,
        );
    }
    println!("graphs: {}", engine.manifest.graphs.len());
    for g in &engine.manifest.graphs {
        println!("  {}", g.name);
    }
    Ok(())
}

/// Batched-scheduler demo: synthetic (or trace-file) requests through the
/// full session API — admission, batched decode, preemption, streaming
/// events, mid-run aborts — on the deterministic sim backend.
fn cmd_schedule() -> Result<()> {
    use paged_eviction::api::{RequestBuilder, RequestId, SeqEvent, Session};
    use paged_eviction::runtime::{FaultPlan, FaultyBackend, SimBackend};
    use paged_eviction::scheduler::{Priority, SchedConfig};
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::{recall, trace};

    let args = ArgSpec::new(
        "paged-eviction schedule",
        "batched continuous-batching rounds over a shared block arena (sim backend)",
    )
    .opt("requests", "16", "synthetic requests to submit (ignored with --trace)")
    .opt("prompt-len", "96", "prompt tokens per request")
    .opt("gen", "48", "output tokens per request")
    .opt("budget", "64", "KV cache budget (tokens)")
    .opt("policy", "paged", "eviction policy, or \"auto\" to let the \
         per-request autotuner pick one from prompt shape, prefix-cache \
         hits and arena pressure")
    .opt("page-size", "8", "KV page size")
    .opt("concurrency", "4", "max concurrent sequences")
    .opt(
        "workers",
        &paged_eviction::scheduler::default_workers().to_string(),
        "scheduler worker threads sharing one arena/swap pool \
         (1 = classic single-threaded round loop; outputs are \
         bit-identical at any count)",
    )
    .opt("arena-blocks", "96", "shared arena capacity (blocks)")
    .opt("swap-bytes", "67108864", "host swap pool byte cap \
         (0 = recompute-only preemption)")
    .opt("watermarks", "0.85,0.95", "admission/preemption watermarks \
         as low,high fractions of the arena")
    .opt("prefix-cache", "on", "share identical prompt-prefix blocks \
         across requests by refcount (on|off)")
    .opt("shared-prefix", "0", "tokens of common prompt prefix across all \
         requests (exercises the prefix cache, e.g. a shared system prompt)")
    .opt("priority", "normal", "priority for requests without a per-entry \
         override (low|normal|high)")
    .opt("stream", "off", "print every SeqEvent as it happens (on|off)")
    .opt("trace", "", "trace file: one request per line, key=value fields \
         (at, prompt_len, gen, policy, budget, priority, deadline, seed)")
    .opt("abort", "", "cancel requests mid-run: comma list of id@step \
         (server-assigned ids, submit order)")
    .opt("faults", "", "deterministic fault-injection spec \
         (see runtime::faults), e.g. transient@r2s4,batch@6,seed=42")
    .opt("seed", "7", "prompt RNG seed")
    .parse_or_exit(2);

    let (watermark_low, watermark_high) = parse_watermarks(args.get("watermarks"))?;
    let cfg = SchedConfig {
        model: "sim".into(),
        page_size: args.get_usize("page-size"),
        max_concurrency: args.get_usize("concurrency"),
        max_live_blocks: args.get_usize("arena-blocks"),
        watermark_low,
        watermark_high,
        swap_bytes: args.get_usize("swap-bytes"),
        prefix_cache: parse_on_off("prefix-cache", args.get("prefix-cache"))?,
        default_policy: args.get("policy").to_string(),
        default_budget: args.get_usize("budget"),
        workers: args.get_usize("workers").max(1),
        ..SchedConfig::default()
    };
    let stream = parse_on_off("stream", args.get("stream"))?;
    let default_priority = Priority::parse(args.get("priority"))?;
    let aborts = parse_aborts(args.get("abort"))?;

    // request specs: trace file entries, or --requests identical ones
    let mut entries: Vec<trace::TraceEntry> = if args.get("trace").is_empty() {
        (0..args.get_usize("requests")).map(|_| trace::TraceEntry::default()).collect()
    } else {
        trace::parse_trace(&std::fs::read_to_string(args.get("trace"))?)?
    };
    entries.sort_by_key(|e| e.at_step); // ids follow submission order

    let mut rng = Pcg32::new(args.get_u64("seed"));
    let cli_prompt_len = args.get_usize("prompt-len");
    let cli_gen = args.get_usize("gen");
    // clamped so the per-request recall tail keeps make_prompt's contract
    // (>= 8 tokens, even length for an even --prompt-len)
    let shared_len =
        args.get_usize("shared-prefix").min(cli_prompt_len.saturating_sub(8)) & !1;
    // the shared system-prompt stand-in: one common prefix, distinct tails
    let shared: Vec<u32> = (0..shared_len).map(|_| rng.below(200)).collect();

    // Materialize every request up front, in entry order: the prompt RNG
    // stream is consumed identically whatever the worker count or the
    // submission timing, so digests stay comparable across runs.
    let mut builders: Vec<Option<RequestBuilder>> = entries
        .iter()
        .map(|e| {
            let plen = e.prompt_len.unwrap_or(cli_prompt_len);
            // make_prompt wants an even tail of >= 8 tokens
            let tail_len = plen.saturating_sub(shared_len).max(8) & !1;
            let mut erng = e.seed.map(Pcg32::new);
            let tail =
                recall::make_prompt(erng.as_mut().unwrap_or(&mut rng), tail_len, 0.4);
            let mut prompt = shared.clone();
            prompt.extend(tail.tokens);
            let mut b = RequestBuilder::new(prompt)
                .max_new_tokens(e.gen.unwrap_or(cli_gen))
                .priority(e.priority.unwrap_or(default_priority))
                // without --stream the demo only reads terminal outputs
                .stream_events(stream);
            if let Some(p) = &e.policy {
                b = b.policy(p.clone());
            }
            if let Some(budget) = e.budget {
                b = b.budget(budget);
            }
            if let Some(d) = e.deadline_steps {
                b = b.deadline_steps(d);
            }
            Some(b)
        })
        .collect();

    if cfg.workers > 1 {
        return schedule_multi(cfg, &entries, builders, &aborts, stream, args.get("faults"));
    }

    // Always serve through the fault wrapper: with no --faults it runs in
    // passthrough mode (no plan, no injection — the `fault_passthrough`
    // bench row pins its overhead), so faulted and clean runs share one
    // code path and their outputs are directly comparable.
    let backend = if args.get("faults").is_empty() {
        FaultyBackend::passthrough(SimBackend::new(cfg.page_size))
    } else {
        let plan = FaultPlan::parse(args.get("faults"))?;
        FaultyBackend::new(SimBackend::new(cfg.page_size), plan)
    };
    let session = Session::with_backend(backend, cfg);
    let mut handles = Vec::new();
    let mut outs = Vec::new();
    let mut cancelled: Vec<u64> = Vec::new();
    let mut next_entry = 0usize;
    let mut step: u64 = 0;
    loop {
        while next_entry < entries.len() && entries[next_entry].at_step <= step {
            let b = builders[next_entry].take().expect("each builder is consumed once");
            handles.push(session.submit(b)?);
            next_entry += 1;
        }
        for &(id, at) in &aborts {
            if at == step {
                let ok = session.cancel(RequestId(id));
                println!("req {id}: {}", if ok { "cancelled" } else { "abort was a no-op" });
                if ok {
                    cancelled.push(id);
                }
            }
        }
        if next_entry >= entries.len() && session.is_idle() {
            break;
        }
        session.step()?;
        step += 1;
        for h in &handles {
            for ev in h.drain() {
                if stream {
                    print_event(h.id().raw(), &ev);
                }
                if let SeqEvent::Finished(o) = ev {
                    outs.push(o);
                }
            }
        }
    }
    // submit-time rejections finish without a step: sweep the tails
    for h in &handles {
        for ev in h.drain() {
            if let SeqEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
    }
    outs.sort_by_key(|o| o.id);
    let (tok_s, preemptions, swap_outs, swap_restores, dropped, hit, cow, n_cancelled, peak, cap) =
        session.with_scheduler(|s| {
            (
                s.throughput_tok_s(),
                s.preemptions,
                s.swap_outs,
                s.swap_restores,
                s.swap_pool().dropped(),
                s.prefix_hit_blocks,
                s.cow_copies,
                s.cancelled(),
                s.arena().stats().peak_used,
                s.arena().capacity(),
            )
        });
    println!(
        "{} requests done ({} cancelled): {:.0} tok/s, {} preemptions ({} swapped out, \
         {} restored, {} dropped), peak arena {} / {} blocks",
        outs.len(),
        n_cancelled,
        tok_s,
        preemptions,
        swap_outs,
        swap_restores,
        dropped,
        peak,
        cap,
    );
    println!(
        "prefix cache: {} prefix-hit blocks, {} cow copies, output digest {:016x}",
        hit,
        cow,
        output_digest(&outs),
    );
    let arena = session.with_scheduler(|s| s.arena().stats());
    println!(
        "arena: lock acquisitions {} ({} contended), cache refills {}, cache drains {}",
        arena.lock_acquisitions,
        arena.contended_acquisitions,
        arena.cache_refills,
        arena.cache_drains,
    );
    let (fault_retries, quarantined, injected) =
        session.with_scheduler(|s| (s.fault_retries, s.quarantined, s.backend().fault_counts()));
    println!(
        "faults: {} injected (transient {}, terminal {}, batch {}, nosnap {}, \
         norestore {}, nogrow {}), fault retries {}, quarantined {}",
        injected.total(),
        injected.transient,
        injected.terminal,
        injected.batch_failures,
        injected.snapshot_refusals,
        injected.restore_failures,
        injected.grow_failures,
        fault_retries,
        quarantined,
    );
    let autotune = session.with_scheduler(|s| s.autotune.clone());
    print_autotune(&autotune);
    for o in &outs {
        println!(
            "  req {:>3}: {:>3} tokens, finish {:?}, ttft {:.2} ms, preempted {}x \
             (swap-restored {}x), retried {}x",
            o.id,
            o.tokens.len(),
            o.finish,
            o.ttft_s * 1e3,
            o.preemptions,
            o.swaps,
            o.retries,
        );
        println!("digest req={} {:016x}", o.id, output_digest(std::slice::from_ref(o)));
    }
    for id in &cancelled {
        println!("  req {id:>3}: cancelled (no output)");
    }
    Ok(())
}

/// The `--policy auto` resolution counters (one line, both schedule
/// drivers). `total=0` on runs that never used the sentinel, so scripts
/// can grep the line unconditionally.
fn print_autotune(stats: &paged_eviction::scheduler::AutotuneStats) {
    let picks = stats.summary();
    println!(
        "autotune: total={}{}{}",
        stats.total(),
        if picks.is_empty() { "" } else { " " },
        picks,
    );
}

/// One `schedule --stream on` event line (shared by the single- and
/// multi-worker drivers so the formats cannot diverge).
fn print_event(id: u64, ev: &paged_eviction::api::SeqEvent) {
    use paged_eviction::api::SeqEvent;
    match ev {
        SeqEvent::Prefilled { ttft_s } => {
            println!("event req={id} kind=prefilled ttft_ms={:.3}", ttft_s * 1e3)
        }
        SeqEvent::Token { tok, step } => {
            println!("event req={id} kind=token tok={tok} step={step}")
        }
        SeqEvent::Preempted { swap } => {
            println!("event req={id} kind=preempted swap={swap}")
        }
        SeqEvent::Resumed => println!("event req={id} kind=resumed"),
        SeqEvent::Finished(o) => println!(
            "event req={id} kind=finished tokens={} finish={:?}",
            o.tokens.len(),
            o.finish
        ),
    }
}

/// The `schedule` demo driven by the multi-worker engine (`--workers N`):
/// same request stream, same output lines (summary, digests, per-request
/// rows), plus worker/steal accounting at the end. Per-request outputs
/// are bit-identical to `--workers 1` — the CI worker-matrix leg compares
/// the digests.
fn schedule_multi(
    cfg: paged_eviction::scheduler::SchedConfig,
    entries: &[paged_eviction::workload::trace::TraceEntry],
    mut builders: Vec<Option<paged_eviction::api::RequestBuilder>>,
    aborts: &[(u64, u64)],
    stream: bool,
    faults: &str,
) -> Result<()> {
    use paged_eviction::api::SeqEvent;
    use paged_eviction::runtime::{FaultCounts, FaultPlan, FaultyBackend, SimBackend};
    use paged_eviction::scheduler::MultiEngine;
    use std::time::{Duration, Instant};

    // Same wrapper discipline as the single-worker path: every worker
    // serves through the fault decorator (passthrough without --faults),
    // each with its own clone of the ONE plan, so fault lanes number each
    // worker's prefills independently (per-worker-stable).
    let plan = if faults.is_empty() { None } else { Some(FaultPlan::parse(faults)?) };
    let page = cfg.page_size;
    let mut engine = MultiEngine::new(cfg, move |_| match &plan {
        None => FaultyBackend::passthrough(SimBackend::new(page)),
        Some(p) => FaultyBackend::new(SimBackend::new(page), p.clone()),
    });

    let t0 = Instant::now();
    let mut outs = Vec::new();
    let mut cancelled: Vec<u64> = Vec::new();
    let mut next_entry = 0usize;
    let mut step: u64 = 0;
    loop {
        while next_entry < entries.len() && entries[next_entry].at_step <= step {
            let b = builders[next_entry].take().expect("each builder is consumed once");
            engine.submit_builder(b)?;
            next_entry += 1;
        }
        for &(id, at) in aborts {
            if at == step {
                let ok = engine.cancel(id);
                println!("req {id}: {}", if ok { "cancelled" } else { "abort was a no-op" });
                if ok {
                    cancelled.push(id);
                }
            }
        }
        if next_entry >= entries.len() && engine.inflight() == 0 {
            break;
        }
        // One demo "step" = one short event-poll tick; the workers run
        // their rounds on their own threads. (--abort steps count ticks
        // of this clock, not scheduler rounds, under --workers > 1.)
        let tick_end = Instant::now() + Duration::from_millis(2);
        loop {
            let left = tick_end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let Some((id, ev)) = engine.next_event(left) else { break };
            if stream {
                print_event(id, &ev);
            }
            if let SeqEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
        step += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let n_workers = engine.workers();
    let dropped = engine.swap_pool().dropped();
    let peak = engine.arena().stats().peak_used;
    let cap = engine.arena().capacity();
    let steals = engine.steals();
    let cross = engine.cross_preempts();
    let arena = engine.arena().stats();
    let (report, backends) = engine.shutdown(Duration::from_secs(10));
    outs.extend(report.leftover);
    outs.sort_by_key(|o| o.id);

    let decoded: u64 = report.workers.iter().map(|w| w.decoded_tokens).sum();
    let preemptions: u64 = report.workers.iter().map(|w| w.preemptions).sum();
    let swap_outs: u64 = report.workers.iter().map(|w| w.swap_outs).sum();
    let swap_restores: u64 = report.workers.iter().map(|w| w.swap_restores).sum();
    let hit: u64 = report.workers.iter().map(|w| w.prefix_hit_blocks).sum();
    let cow: u64 = report.workers.iter().map(|w| w.cow_copies).sum();
    let fault_retries: u64 = report.workers.iter().map(|w| w.fault_retries).sum();
    let quarantined: u64 = report.workers.iter().map(|w| w.quarantined).sum();
    let n_cancelled: u64 = report.workers.iter().map(|w| w.cancelled).sum();
    let tok_s = if elapsed > 0.0 { decoded as f64 / elapsed } else { 0.0 };
    let mut injected = FaultCounts::default();
    for b in &backends {
        let c = b.fault_counts();
        injected.transient += c.transient;
        injected.terminal += c.terminal;
        injected.batch_failures += c.batch_failures;
        injected.snapshot_refusals += c.snapshot_refusals;
        injected.restore_failures += c.restore_failures;
        injected.grow_failures += c.grow_failures;
    }
    println!(
        "{} requests done ({} cancelled): {:.0} tok/s, {} preemptions ({} swapped out, \
         {} restored, {} dropped), peak arena {} / {} blocks",
        outs.len(),
        n_cancelled,
        tok_s,
        preemptions,
        swap_outs,
        swap_restores,
        dropped,
        peak,
        cap,
    );
    println!(
        "prefix cache: {} prefix-hit blocks, {} cow copies, output digest {:016x}",
        hit,
        cow,
        output_digest(&outs),
    );
    println!(
        "arena: lock acquisitions {} ({} contended), cache refills {}, cache drains {}",
        arena.lock_acquisitions,
        arena.contended_acquisitions,
        arena.cache_refills,
        arena.cache_drains,
    );
    println!(
        "faults: {} injected (transient {}, terminal {}, batch {}, nosnap {}, \
         norestore {}, nogrow {}), fault retries {}, quarantined {}",
        injected.total(),
        injected.transient,
        injected.terminal,
        injected.batch_failures,
        injected.snapshot_refusals,
        injected.restore_failures,
        injected.grow_failures,
        fault_retries,
        quarantined,
    );
    let mut autotune = paged_eviction::scheduler::AutotuneStats::default();
    for w in &report.workers {
        autotune.merge(&w.autotune);
    }
    print_autotune(&autotune);
    for o in &outs {
        println!(
            "  req {:>3}: {:>3} tokens, finish {:?}, ttft {:.2} ms, preempted {}x \
             (swap-restored {}x), retried {}x",
            o.id,
            o.tokens.len(),
            o.finish,
            o.ttft_s * 1e3,
            o.preemptions,
            o.swaps,
            o.retries,
        );
        println!("digest req={} {:016x}", o.id, output_digest(std::slice::from_ref(o)));
    }
    for id in &cancelled {
        println!("  req {id:>3}: cancelled (no output)");
    }
    println!("workers: {n_workers} threads, steals {steals}, cross preempts {cross}");
    for w in &report.workers {
        println!(
            "  worker {}: {} rounds ({} busy, {:.0}% util), {} tokens decoded",
            w.worker,
            w.rounds,
            w.busy_rounds,
            w.utilization() * 100.0,
            w.decoded_tokens,
        );
    }
    Ok(())
}

/// Metrics from one `slo` scenario × worker-count run — one row of
/// `BENCH_slo.json` (schema `slo-v1`), gated by `tools/bench_gate.py --slo`.
struct SloRow {
    scenario: String,
    workers: usize,
    /// The `--policy` flag the replay ran under (may be `"auto"`).
    policy: String,
    /// Completed requests per RESOLVED policy (`RequestOutput::policy`,
    /// so `auto` rows show what the autotuner actually picked).
    policy_counts: std::collections::BTreeMap<String, u64>,
    requests: usize,
    completed: usize,
    digest: u64,
    elapsed_s: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    tpot_p50_ms: f64,
    tpot_p99_ms: f64,
    /// Fraction of completed requests meeting BOTH SLO ceilings.
    slo_attainment: f64,
    /// Output tokens/s counting only SLO-meeting requests.
    goodput_tok_s: f64,
    decoded_tokens: u64,
    preemptions: u64,
    swap_outs: u64,
    swap_restores: u64,
    cow_copies: u64,
    prefix_hit_blocks: u64,
    steals: u64,
    cross_preempts: u64,
    chunk_prefills: u64,
    /// Global-arena-lock acquisitions over the whole replay (all workers).
    lock_acquisitions: u64,
    /// Acquisitions that found the lock held (try_lock failed first).
    contended_acquisitions: u64,
    /// Worker slot-cache leases from the global free list.
    cache_refills: u64,
    /// Dry-arena drains of peer slot caches (phantom-OOM preventions).
    cache_drains: u64,
}

/// Replay named SLO scenarios through [`MultiEngine`] at one or more
/// worker counts. Traffic is fully seeded (same seed → same trace → same
/// per-request token streams), so per-scenario output digests must match
/// across worker counts — this driver *fails* if they do not, which is
/// what the `slo-smoke` CI job leans on. Latency rows go to stdout and,
/// with `--json`, to a `BENCH_slo.json` the SLO gate asserts against.
fn cmd_slo() -> Result<()> {
    use paged_eviction::workload::Scenario;

    let args = ArgSpec::new(
        "paged-eviction slo",
        "SLO workload replay: seeded multi-tenant traffic through the \
         multi-worker engine, tail-latency + goodput + digest rows",
    )
    .opt(
        "scenario",
        "bursty-chat,longbench-replay",
        "comma list of scenarios \
         (bursty-chat|longbench-replay|diurnal-mixed|saturate-steal|all)",
    )
    .opt("workers", "1,4", "comma list of worker counts to replay at")
    .opt("policy", "paged", "eviction policy for every request, or \
         \"auto\" to let the per-request autotuner pick")
    .opt("concurrency", "4", "max concurrent sequences per worker")
    .opt("arena-blocks", "320", "shared arena capacity (blocks)")
    .opt("page-size", "16", "KV page size")
    .opt("json", "", "write BENCH_slo.json-style rows to this path")
    .opt("seed", "42", "trace synthesis seed")
    .parse_or_exit(2);

    let seed = args.get_u64("seed");
    let policy = args.get("policy");
    validate_request_policy(policy)?; // "auto" included
    let names: Vec<String> = if args.get("scenario") == "all" {
        Scenario::builtin_names().iter().map(|s| s.to_string()).collect()
    } else {
        args.get("scenario")
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    };
    anyhow::ensure!(!names.is_empty(), "--scenario lists no scenarios");
    let worker_counts: Vec<usize> = args
        .get("workers")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --workers entry {s:?}"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!worker_counts.is_empty(), "--workers lists no counts");

    let mut rows: Vec<SloRow> = Vec::new();
    for name in &names {
        let sc = Scenario::builtin(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario {name:?} (want one of {:?})",
                Scenario::builtin_names()
            )
        })?;
        let mut digests: Vec<(usize, u64)> = Vec::new();
        for &w in &worker_counts {
            let row = run_slo_scenario(
                &sc,
                w.max(1),
                seed,
                policy,
                args.get_usize("concurrency"),
                args.get_usize("arena-blocks"),
                args.get_usize("page-size"),
            )?;
            println!(
                "scenario {} workers {} policy {}: {}/{} done in {:.2}s, ttft p50/p99 \
                 {:.1}/{:.1} ms, tpot p50/p99 {:.2}/{:.2} ms, attainment {:.2}, \
                 goodput {:.0} tok/s",
                row.scenario,
                row.workers,
                row.policy,
                row.completed,
                row.requests,
                row.elapsed_s,
                row.ttft_p50_ms,
                row.ttft_p99_ms,
                row.tpot_p50_ms,
                row.tpot_p99_ms,
                row.slo_attainment,
                row.goodput_tok_s,
            );
            println!(
                "  preempts {} (swap out {}, restored {}), cow {}, prefix hits {}, \
                 steals {}, cross preempts {}, chunk prefills {}",
                row.preemptions,
                row.swap_outs,
                row.swap_restores,
                row.cow_copies,
                row.prefix_hit_blocks,
                row.steals,
                row.cross_preempts,
                row.chunk_prefills,
            );
            println!(
                "  arena: lock acquisitions {} ({} contended), cache refills {}, \
                 cache drains {}",
                row.lock_acquisitions,
                row.contended_acquisitions,
                row.cache_refills,
                row.cache_drains,
            );
            let by_policy: Vec<String> =
                row.policy_counts.iter().map(|(p, n)| format!("{p}={n}")).collect();
            println!("  policies: {}", by_policy.join(" "));
            println!("digest scenario={} workers={} {:016x}", row.scenario, row.workers, row.digest);
            digests.push((row.workers, row.digest));
            rows.push(row);
        }
        // the determinism contract this whole harness rides on: placement
        // must never change any request's output
        if let Some(&(w0, d0)) = digests.first() {
            for &(w, d) in &digests[1..] {
                anyhow::ensure!(
                    d == d0,
                    "scenario {name}: digest {d:016x} at workers={w} differs from \
                     {d0:016x} at workers={w0}"
                );
            }
        }
    }

    if !args.get("json").is_empty() {
        let json = render_slo_json(seed, &rows);
        std::fs::write(args.get("json"), &json)?;
        println!("wrote {} rows to {}", rows.len(), args.get("json"));
    }
    Ok(())
}

/// Replay one scenario at one worker count and measure it.
fn run_slo_scenario(
    sc: &paged_eviction::workload::Scenario,
    workers: usize,
    seed: u64,
    policy: &str,
    concurrency: usize,
    arena_blocks: usize,
    page_size: usize,
) -> Result<SloRow> {
    use paged_eviction::api::{RequestBuilder, SeqEvent};
    use paged_eviction::runtime::{FaultyBackend, SimBackend};
    use paged_eviction::scheduler::{MultiEngine, SchedConfig};
    use paged_eviction::util::stats::Histogram;
    use std::time::{Duration, Instant};

    let cfg = SchedConfig {
        model: "sim".into(),
        page_size,
        max_concurrency: concurrency,
        max_live_blocks: arena_blocks,
        prefix_cache: true,
        default_policy: policy.to_string(),
        default_budget: 1024,
        workers,
        prefill_chunk: sc.prefill_chunk,
        ..SchedConfig::default()
    };
    let reqs = sc.synthesize(seed);
    // materialize every builder up front, in arrival order: ids and token
    // streams are then independent of worker count and wall-clock pacing
    let mut builders: Vec<Option<RequestBuilder>> = reqs
        .iter()
        .map(|r| Some(RequestBuilder::new(r.prompt.clone()).max_new_tokens(r.max_new_tokens)))
        .collect();

    let page = cfg.page_size;
    let mut engine =
        MultiEngine::new(cfg, move |_| FaultyBackend::passthrough(SimBackend::new(page)));
    let t0 = Instant::now();
    let mut outs = Vec::new();
    let mut next = 0usize;
    loop {
        let now_s = t0.elapsed().as_secs_f64();
        while next < reqs.len() && reqs[next].at_s <= now_s {
            let b = builders[next].take().expect("each builder is consumed once");
            engine.submit_builder(b)?;
            next += 1;
        }
        if next >= reqs.len() && engine.inflight() == 0 {
            break;
        }
        // short event-poll tick; workers run rounds on their own threads
        let tick_end = Instant::now() + Duration::from_millis(2);
        loop {
            let left = tick_end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let Some((_, ev)) = engine.next_event(left) else { break };
            if let SeqEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64().max(1e-9);
    let steals = engine.steals();
    let cross_preempts = engine.cross_preempts();
    let arena = engine.arena().stats();
    let (report, _backends) = engine.shutdown(Duration::from_secs(10));
    outs.extend(report.leftover);
    outs.sort_by_key(|o| o.id);
    anyhow::ensure!(!outs.is_empty(), "scenario {} produced no outputs", sc.name);

    let mut ttft = Histogram::new();
    let mut tpot = Histogram::new();
    let mut met = 0usize;
    let mut good_tokens = 0u64;
    for o in &outs {
        let ttft_ms = o.ttft_s * 1e3;
        let tpot_ms = o.tpot_s * 1e3;
        ttft.add(ttft_ms);
        if o.tokens.len() > 1 {
            tpot.add(tpot_ms);
        }
        if ttft_ms <= sc.slo.ttft_ms && tpot_ms <= sc.slo.tpot_ms {
            met += 1;
            good_tokens += o.tokens.len() as u64;
        }
    }
    let (tpot_p50, tpot_p99) =
        if tpot.is_empty() { (0.0, 0.0) } else { (tpot.pctl(0.50), tpot.pctl(0.99)) };
    // counted by the policy each request actually RAN under — for a fixed
    // --policy that's one bucket; under "auto" it is the autotuner's mix
    let mut policy_counts = std::collections::BTreeMap::new();
    for o in &outs {
        *policy_counts.entry(o.policy.clone()).or_insert(0u64) += 1;
    }
    Ok(SloRow {
        scenario: sc.name.to_string(),
        workers,
        policy: policy.to_string(),
        policy_counts,
        requests: reqs.len(),
        completed: outs.len(),
        digest: output_digest(&outs),
        elapsed_s,
        ttft_p50_ms: ttft.pctl(0.50),
        ttft_p99_ms: ttft.pctl(0.99),
        tpot_p50_ms: tpot_p50,
        tpot_p99_ms: tpot_p99,
        slo_attainment: met as f64 / outs.len() as f64,
        goodput_tok_s: good_tokens as f64 / elapsed_s,
        decoded_tokens: report.workers.iter().map(|w| w.decoded_tokens).sum(),
        preemptions: report.workers.iter().map(|w| w.preemptions).sum(),
        swap_outs: report.workers.iter().map(|w| w.swap_outs).sum(),
        swap_restores: report.workers.iter().map(|w| w.swap_restores).sum(),
        cow_copies: report.workers.iter().map(|w| w.cow_copies).sum(),
        prefix_hit_blocks: report.workers.iter().map(|w| w.prefix_hit_blocks).sum(),
        steals,
        cross_preempts,
        chunk_prefills: report.workers.iter().map(|w| w.chunk_prefills).sum(),
        lock_acquisitions: arena.lock_acquisitions,
        contended_acquisitions: arena.contended_acquisitions,
        cache_refills: arena.cache_refills,
        cache_drains: arena.cache_drains,
    })
}

/// Hand-rolled `BENCH_slo.json` (schema `slo-v1`) — mirrors the
/// dependency-free style of the micro-bench JSON emitter.
fn render_slo_json(seed: u64, rows: &[SloRow]) -> String {
    fn f(x: f64) -> String {
        if x.is_finite() {
            format!("{x:.6}")
        } else {
            "null".to_string()
        }
    }
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"slo-v1\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let policy_counts: Vec<String> =
            r.policy_counts.iter().map(|(p, n)| format!("\"{p}\": {n}")).collect();
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"workers\": {}, \"policy\": \"{}\", \
             \"policy_counts\": {{{}}}, \"requests\": {}, \
             \"completed\": {}, \"digest\": \"{:016x}\", \"elapsed_s\": {}, \
             \"ttft_p50_ms\": {}, \"ttft_p99_ms\": {}, \"tpot_p50_ms\": {}, \
             \"tpot_p99_ms\": {}, \"slo_attainment\": {}, \"goodput_tok_s\": {}, \
             \"decoded_tokens\": {}, \"preemptions\": {}, \"swap_outs\": {}, \
             \"swap_restores\": {}, \"cow_copies\": {}, \"prefix_hit_blocks\": {}, \
             \"steals\": {}, \"cross_preempts\": {}, \"chunk_prefills\": {}, \
             \"lock_acquisitions\": {}, \"contended_acquisitions\": {}, \
             \"cache_refills\": {}, \"cache_drains\": {}, \
             \"preempt_per_s\": {}, \"swap_per_s\": {}, \"cow_per_s\": {}, \
             \"steal_per_s\": {}, \"cross_preempt_per_s\": {}}}{}\n",
            r.scenario,
            r.workers,
            r.policy,
            policy_counts.join(", "),
            r.requests,
            r.completed,
            r.digest,
            f(r.elapsed_s),
            f(r.ttft_p50_ms),
            f(r.ttft_p99_ms),
            f(r.tpot_p50_ms),
            f(r.tpot_p99_ms),
            f(r.slo_attainment),
            f(r.goodput_tok_s),
            r.decoded_tokens,
            r.preemptions,
            r.swap_outs,
            r.swap_restores,
            r.cow_copies,
            r.prefix_hit_blocks,
            r.steals,
            r.cross_preempts,
            r.chunk_prefills,
            r.lock_acquisitions,
            r.contended_acquisitions,
            r.cache_refills,
            r.cache_drains,
            f(r.preemptions as f64 / r.elapsed_s),
            f(r.swap_outs as f64 / r.elapsed_s),
            f(r.cow_copies as f64 / r.elapsed_s),
            f(r.steals as f64 / r.elapsed_s),
            f(r.cross_preempts as f64 / r.elapsed_s),
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn cmd_simulate() -> Result<()> {
    let args = ArgSpec::new(
        "paged-eviction simulate",
        "accuracy-simulator sweep row (see DESIGN.md §4 for what this models)",
    )
    .opt("dataset", "govreport", "govreport|multinews|hotpotqa|multifieldqa|qasper")
    .opt("policy", "paged", "eviction policy")
    .opt("budget", "1024", "cache budget tokens")
    .opt("page-size", "16", "page size")
    .opt("episodes", "32", "episodes to average")
    .opt("seed", "0", "base seed")
    .parse_or_exit(2);
    let d = sim::datasets::dataset(args.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let cfg = sim::SimConfig {
        budget: args.get_usize("budget"),
        page_size: args.get_usize("page-size"),
        seed: args.get_u64("seed"),
        ..Default::default()
    };
    let p = make_policy(args.get("policy"))?;
    let r = sim::attention_sim::simulate_mean(d, p.as_ref(), &cfg, args.get_usize("episodes"));
    println!(
        "{} {} budget={} -> score {:.2} (coverage {:.3}, needles {:.2}, partial_blocks {})",
        args.get("dataset"),
        args.get("policy"),
        cfg.budget,
        r.score,
        r.coverage,
        r.needles_retained,
        r.partial_blocks,
    );
    Ok(())
}
