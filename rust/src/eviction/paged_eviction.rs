//! PagedEviction — the paper's method (Algorithms 1–3).
//!
//! Prefill: token-level eviction by the attention-free proxy
//! `S_i = ||V_i|| / ||K_i||` down to the cache budget, applied BEFORE the
//! retained tokens are paginated (no cross-block movement).
//!
//! Decode: when the newest block fills (`L % B == 0`) and the cache is over
//! budget, score every block as the mean of its tokens' proxies and evict
//! the single lowest-scoring whole page — one table update every B steps,
//! no partial pages, no kernel changes.

use super::{top_k_ascending, Decision, EvictionPolicy, PrefillScores, CH_VK_RATIO};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone)]
pub struct PagedEviction {
    /// Never evict the most recent `protect_recent_blocks` blocks (the
    /// newest block is always protected; the paper's Figure 1 evicts among
    /// the older pages).
    pub protect_recent_blocks: usize,
    /// Which score channel drives decisions (CH_VK_RATIO for the paper's
    /// proxy; kept configurable for the ablation benches).
    pub channel: usize,
    /// `true` (paper): higher channel value = more important.
    pub higher_is_important: bool,
}

impl Default for PagedEviction {
    fn default() -> Self {
        PagedEviction {
            protect_recent_blocks: 1,
            channel: CH_VK_RATIO,
            higher_is_important: true,
        }
    }
}

impl EvictionPolicy for PagedEviction {
    fn name(&self) -> &'static str {
        "paged"
    }

    fn structured(&self) -> bool {
        true
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        if scores.len <= budget {
            return (0..scores.len).collect();
        }
        let ch = &scores.channels[self.channel];
        if self.higher_is_important {
            top_k_ascending(ch, budget)
        } else {
            super::bottom_k_ascending(ch, budget)
        }
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        // Trigger only when the just-appended token filled the newest block
        // (paper Alg. 3: L % B == 0) and we are past the budget.
        if !cache.last_block_full() || cache.live_tokens() <= budget {
            return Decision::Keep;
        }
        let n = cache.n_blocks();
        let protected = self.protect_recent_blocks.max(1);
        if n <= protected {
            return Decision::Keep;
        }
        // Single O(blocks * B) scan over borrowed state: no heap allocation
        // on the steady-state decode path (the returned Decision carries
        // only a block index). total_cmp keeps a NaN block score from
        // winning the eviction pick.
        let candidates = &cache.blocks()[..n - protected];
        let pick = candidates
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let s = b.mean_score(self.channel);
                (i, if self.higher_is_important { s } else { -s })
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(i, _)| i);
        match pick {
            Some(i) => Decision::EvictBlock(i),
            None => Decision::Keep,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_blocks(block_scores: &[f32], bs: usize) -> SeqCache {
        let mut c = SeqCache::new(bs, block_scores.len() + 2);
        let toks: Vec<(u32, [f32; 3])> = block_scores
            .iter()
            .flat_map(|&s| std::iter::repeat((0u32, [s, s, s])).take(bs))
            .enumerate()
            .map(|(i, (_, sc))| (i as u32, sc))
            .collect();
        let n = toks.len() as u32;
        c.load_prefill(&toks, n);
        c
    }

    #[test]
    fn prefill_keeps_top_vk_ratio() {
        let s = PrefillScores {
            channels: [
                vec![0.1, 0.9, 0.5, 0.8, 0.2],
                vec![0.0; 5],
                vec![0.0; 5],
            ],
            len: 5,
        };
        let p = PagedEviction::default();
        assert_eq!(p.prefill_keep(&s, 3), vec![1, 2, 3]);
        assert_eq!(p.prefill_keep(&s, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(p.prefill_keep(&s, 8), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn decode_waits_for_full_block() {
        let bs = 4;
        let mut c = cache_with_blocks(&[0.5, 0.1, 0.9], bs);
        let p = PagedEviction::default();
        // over budget but newest block not full -> Keep
        c.ensure_block();
        c.append([0.7; 3]);
        assert_eq!(p.post_append(&c, 8), Decision::Keep);
        // fill the block -> evict lowest-mean block (index 1, score 0.1)
        for _ in 0..bs - 1 {
            c.ensure_block();
            c.append([0.7; 3]);
        }
        assert_eq!(p.post_append(&c, 8), Decision::EvictBlock(1));
    }

    #[test]
    fn decode_under_budget_keeps() {
        let c = cache_with_blocks(&[0.5, 0.1], 4);
        let p = PagedEviction::default();
        assert_eq!(p.post_append(&c, 8), Decision::Keep);
        assert_eq!(p.post_append(&c, 9), Decision::Keep);
    }

    #[test]
    fn newest_block_protected() {
        // lowest score in the newest block; must evict the second-lowest
        let c = cache_with_blocks(&[0.5, 0.3, 0.01], 4);
        let p = PagedEviction::default();
        assert_eq!(p.post_append(&c, 4), Decision::EvictBlock(1));
    }

    #[test]
    fn single_block_never_evicted() {
        let c = cache_with_blocks(&[0.5], 4);
        let p = PagedEviction::default();
        assert_eq!(p.post_append(&c, 1), Decision::Keep);
    }

    #[test]
    fn eviction_loop_maintains_budget_oscillation() {
        // Live count must oscillate in (budget - B, budget + B].
        let bs = 4;
        let budget = 3 * bs;
        let mut c = cache_with_blocks(&[0.5, 0.4, 0.3], bs);
        let p = PagedEviction::default();
        for step in 0..40 {
            c.ensure_block();
            c.append([0.2 + (step as f32) * 1e-3; 3]);
            if let Decision::EvictBlock(i) = p.post_append(&c, budget) {
                c.evict_block(i);
            }
            assert!(c.live_tokens() <= budget + bs, "step {step}");
            assert!(c.live_tokens() + bs > budget, "step {step}");
            c.check_invariants().unwrap();
        }
        // Structured: zero partial pages, exactly one table update per B
        // decode tokens beyond alloc.
        assert_eq!(c.partial_blocks(), 0);
    }
}
