//! Full Cache baseline: no eviction at all. The cache grows without bound
//! (bucket migrations handled by the runtime) — the paper's accuracy
//! upper bound and throughput lower bound.

use super::{Decision, EvictionPolicy, PrefillScores};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone, Default)]
pub struct FullCache;

impl EvictionPolicy for FullCache {
    fn name(&self) -> &'static str {
        "full"
    }

    fn structured(&self) -> bool {
        true
    }

    fn prefill_keep(&self, scores: &PrefillScores, _budget: usize) -> Vec<usize> {
        (0..scores.len).collect()
    }

    fn post_append(&self, _cache: &SeqCache, _budget: usize) -> Decision {
        Decision::Keep
    }

    /// The whole prompt stays resident: admission must charge it even when
    /// `budget < prompt_len` (the budget is ignored above, too).
    fn prefill_resident(&self, prompt_len: usize, _budget: usize) -> usize {
        prompt_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_evicts() {
        let p = FullCache;
        let s = PrefillScores {
            channels: [vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]],
            len: 10,
        };
        assert_eq!(p.prefill_keep(&s, 2).len(), 10, "budget is ignored");
        let mut c = SeqCache::new(4, 4);
        c.load_prefill(&(0..8).map(|i| (i, [0.0; 3])).collect::<Vec<_>>(), 8);
        assert_eq!(p.post_append(&c, 1), Decision::Keep);
    }
}
