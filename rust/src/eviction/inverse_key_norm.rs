//! Inverse Key L2-Norm baseline (Devoto et al. 2024): keys with LOW L2 norm
//! correlate with HIGH cumulative attention, so the policy evicts the token
//! with the globally highest key norm. Unstructured: every decode step
//! scans all live tokens and hole-punches one, fragmenting pages (paper
//! Fig. 6) — a block is freed only after all of its tokens die.

use std::cell::RefCell;

use super::{
    bottom_k_ascending, Decision, EvictionPolicy, KillList, LiveTok, PrefillScores, CH_KEY_L2,
};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone, Default)]
pub struct InverseKeyNorm;

impl EvictionPolicy for InverseKeyNorm {
    fn name(&self) -> &'static str {
        "inverse_key_norm"
    }

    fn structured(&self) -> bool {
        false
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        if scores.len <= budget {
            return (0..scores.len).collect();
        }
        // keep the lowest-norm keys
        bottom_k_ascending(&scores.channels[CH_KEY_L2], budget)
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        unstructured_evict_worst(cache, budget, CH_KEY_L2, /*higher_is_worse=*/ true)
    }

    /// Hole-punches tokens inside pages: shared prefix pages must be
    /// copied-on-write before this policy's decode decisions run.
    fn kills_tokens(&self) -> bool {
        true
    }
}

thread_local! {
    /// Reusable live-token scan buffer for the unstructured policies:
    /// steady-state decode refills it in place instead of allocating a
    /// fresh list every step. Thread-local (rather than a per-policy
    /// `Mutex`) so the parallel episode simulator — which shares one
    /// `Sync` policy instance across threads — scans without contention
    /// while each thread keeps the zero-allocation property.
    static SCAN_SCRATCH: RefCell<Vec<LiveTok>> = RefCell::new(Vec::new());
}

/// Shared decode-path logic for unstructured baselines: kill the globally
/// worst live tokens (excluding the just-appended one) until within budget.
/// O(n) selection over a thread-local scratch buffer; the kill list rides
/// inline in the returned [`Decision`] (`KillList` small-vec), so the
/// steady-state path performs zero heap allocations end to end.
pub(crate) fn unstructured_evict_worst(
    cache: &SeqCache,
    budget: usize,
    channel: usize,
    higher_is_worse: bool,
) -> Decision {
    let live = cache.live_tokens();
    if live <= budget {
        return Decision::Keep;
    }
    let newest_pos = cache.next_position().saturating_sub(1);
    SCAN_SCRATCH.with(|scratch| {
        let mut tokens = scratch.borrow_mut();
        cache.collect_live_tokens(&mut tokens);
        tokens.retain(|&(_, _, pos, _)| pos != newest_pos);
        let over = (live - budget).min(tokens.len());
        if over == 0 {
            return Decision::Keep;
        }
        // Worst-first total order: channel score (reversed when higher is
        // worse), ties broken by (block, offset) so the kill set is fully
        // deterministic and NaN scores cannot poison the partition.
        let cmp = |a: &LiveTok, b: &LiveTok| {
            let (sa, sb) = (a.3[channel], b.3[channel]);
            let ord = if higher_is_worse { sb.total_cmp(&sa) } else { sa.total_cmp(&sb) };
            ord.then_with(|| (a.0, a.1).cmp(&(b.0, b.1)))
        };
        if over < tokens.len() {
            tokens.select_nth_unstable_by(over - 1, cmp);
        }
        // worst-first within the selected prefix, matching the order the
        // former full sort emitted (callers apply kills in list order)
        tokens[..over].sort_unstable_by(cmp);
        let mut kills = KillList::new();
        for &(bi, off, _, _) in &tokens[..over] {
            kills.push(bi, off);
        }
        Decision::KillTokens(kills)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_keeps_low_norm() {
        let s = PrefillScores {
            channels: [
                vec![0.0; 5],
                vec![5.0, 1.0, 4.0, 0.5, 3.0],
                vec![0.0; 5],
            ],
            len: 5,
        };
        let p = InverseKeyNorm::default();
        assert_eq!(p.prefill_keep(&s, 2), vec![1, 3]);
    }

    #[test]
    fn decode_kills_global_max_norm() {
        let p = InverseKeyNorm::default();
        let bs = 4;
        let mut c = SeqCache::new(bs, 4);
        // 8 prefill tokens with norms 1..8 (token 7 = norm 8 worst)
        let toks: Vec<(u32, [f32; 3])> =
            (0..8).map(|i| (i, [0.0, (i + 1) as f32, 0.0])).collect();
        c.load_prefill(&toks, 8);
        c.ensure_block();
        c.append([0.0, 0.5, 0.0]); // the newest token — excluded from scan
        match p.post_append(&c, 8) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(1, 3)]), // token 7
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn newest_token_never_selfevicted() {
        let p = InverseKeyNorm::default();
        let mut c = SeqCache::new(4, 4);
        let toks: Vec<(u32, [f32; 3])> = (0..4).map(|i| (i, [0.0, 1.0, 0.0])).collect();
        c.load_prefill(&toks, 4);
        c.ensure_block();
        c.append([0.0, 99.0, 0.0]); // newest has the worst norm
        match p.post_append(&c, 4) {
            Decision::KillTokens(ts) => {
                assert_eq!(ts.len(), 1);
                assert_ne!(ts.get(0), (1, 0), "must not kill the newest token");
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn fragmentation_emerges() {
        // Random norms spread kills across blocks -> partial pages linger.
        let p = InverseKeyNorm::default();
        let bs = 4;
        let budget = 12;
        let mut c = SeqCache::new(bs, 8);
        let toks: Vec<(u32, [f32; 3])> = (0..12)
            .map(|i| (i, [0.0, ((i * 7919) % 13) as f32, 0.0]))
            .collect();
        c.load_prefill(&toks, 12);
        let mut saw_partial = false;
        for s in 0..16 {
            assert!(c.ensure_block(), "step {s}: pool exhausted");
            c.append([0.0, ((s * 104729) % 17) as f32, 0.0]);
            if let Decision::KillTokens(ts) = p.post_append(&c, budget) {
                for (bi, off) in ts {
                    c.kill_token(bi, off);
                }
            }
            saw_partial |= c.partial_blocks() > 0;
            c.check_invariants().unwrap();
            assert!(c.live_tokens() <= budget);
        }
        assert!(saw_partial, "unstructured eviction should fragment pages");
    }
}
