//! KV-cache eviction policies — the paper's contribution (PagedEviction)
//! plus every attention-free baseline it compares against (§5.2):
//! Full Cache, StreamingLLM, Inverse Key L2-Norm, KeyDiff.
//!
//! A policy acts at exactly two points, mirroring the paper's split:
//!
//!  * **prefill** (`prefill_keep`): given the per-token importance channels
//!    for the whole prompt, choose which tokens survive down to the cache
//!    budget — token-level, done BEFORE pagination so no cross-block
//!    movement is ever needed (paper Alg. 2);
//!  * **decode** (`post_append`): called after every generated token with a
//!    read-only view of the cache, returns a [`Decision`]. Structured
//!    policies act only when the newest block fills (paper Alg. 3);
//!    unstructured baselines return per-step token kills.
//!
//! Importance channels (computed by the L1 kernels, attention-free):
//!   0 = ||V||/||K|| ratio (PagedEviction, higher = keep)
//!   1 = key L2 norm       (InverseKeyNorm, lower = keep)
//!   2 = KeyDiff cosine    (KeyDiff, lower = keep / higher = redundant)

mod attention_gate;
pub mod auto;
mod full_cache;
mod inverse_key_norm;
mod keydiff;
mod paged_eviction;
pub mod registry;
mod self_attn_guided;
mod streaming_llm;

pub use attention_gate::AttentionGate;
pub use auto::AUTO_POLICY;
pub use full_cache::FullCache;
pub use inverse_key_norm::InverseKeyNorm;
pub use keydiff::KeyDiff;
pub use paged_eviction::PagedEviction;
pub use registry::{make_policy, validate_request_policy, PolicyInfo, REGISTRY};
pub use self_attn_guided::SelfAttnGuided;
pub use streaming_llm::StreamingLlm;

use crate::kvcache::SeqCache;

/// Channel indices into the score bundle.
pub const CH_VK_RATIO: usize = 0;
pub const CH_KEY_L2: usize = 1;
pub const CH_KEYDIFF: usize = 2;

/// Per-token importance channels for a (padded) prompt, aggregated over
/// layers. `channels[c][i]` is channel `c` of prompt token `i`, `0 <= i <
/// len`.
pub struct PrefillScores {
    pub channels: [Vec<f32>; 3],
    pub len: usize,
}

impl PrefillScores {
    /// Aggregate the graph output `[3, L, P]` (flattened row-major) by
    /// averaging over layers — the shared-block-table convention
    /// (DESIGN.md §8).
    pub fn from_graph_output(flat: &[f32], n_layers: usize, p: usize, len: usize) -> Self {
        assert_eq!(flat.len(), 3 * n_layers * p);
        let mut channels = [vec![0.0; len], vec![0.0; len], vec![0.0; len]];
        for c in 0..3 {
            for l in 0..n_layers {
                let base = (c * n_layers + l) * p;
                for i in 0..len {
                    channels[c][i] += flat[base + i];
                }
            }
            for v in channels[c].iter_mut() {
                *v /= n_layers as f32;
            }
        }
        PrefillScores { channels, len }
    }
}

/// Per-token ACCUMULATED attention mass for one running sequence — the
/// optional per-step feedback channel attention-guided policies consume
/// (`DecodeBackend::attention_feedback`). Indexed by ORIGINAL sequence
/// position (`mass[pos]`), the same coordinate `SeqCache::live_tokens`
/// reports, so the layout is independent of how the cache paged or evicted
/// its entries. Backends without an attention readout return `None` and
/// the policies fall back to their score-channel proxy — the PJRT path
/// ships zero kernel changes, mirroring the paper's constraint.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AttnFeedback {
    /// `mass[pos]` = attention mass position `pos` has accumulated so far.
    pub mass: Vec<f32>,
}

impl AttnFeedback {
    /// Mass at `pos`; positions the backend never reported score 0
    /// (least-attended), so a stale/short vector degrades safely.
    pub fn mass_at(&self, pos: usize) -> f32 {
        self.mass.get(pos).copied().unwrap_or(0.0)
    }

    pub fn len(&self) -> usize {
        self.mass.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mass.is_empty()
    }
}

/// Aggregate a decode-step score output `[3, L]` to per-channel means.
pub fn aggregate_decode_scores(flat: &[f32], n_layers: usize) -> [f32; 3] {
    assert_eq!(flat.len(), 3 * n_layers);
    let mut out = [0.0f32; 3];
    for c in 0..3 {
        for l in 0..n_layers {
            out[c] += flat[c * n_layers + l];
        }
        out[c] /= n_layers as f32;
    }
    out
}

/// Kill list carried by [`Decision::KillTokens`]: (logical block, offset)
/// pairs in kill order. Inline small-vec — steady-state unstructured
/// eviction kills exactly `live - budget` tokens per step (normally one),
/// so the common case fits inline and the whole decode decision path is
/// allocation-free end to end; rare bursts spill to the heap.
const KILL_INLINE: usize = 8;

#[derive(Debug, Clone)]
pub struct KillList {
    inline: [(u32, u32); KILL_INLINE],
    spill: Vec<(u32, u32)>,
    len: usize,
}

impl KillList {
    pub const INLINE: usize = KILL_INLINE;

    pub fn new() -> KillList {
        KillList { inline: [(0, 0); KILL_INLINE], spill: Vec::new(), len: 0 }
    }

    pub fn push(&mut self, block_idx: usize, off: usize) {
        let entry = (block_idx as u32, off as u32);
        if self.len < Self::INLINE {
            self.inline[self.len] = entry;
        } else {
            self.spill.push(entry);
        }
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, i: usize) -> (usize, usize) {
        assert!(i < self.len, "kill index {i} out of range {}", self.len);
        let (b, o) = if i < Self::INLINE {
            self.inline[i]
        } else {
            self.spill[i - Self::INLINE]
        };
        (b as usize, o as usize)
    }

    pub fn iter(&self) -> KillListIter<'_> {
        KillListIter { list: self, i: 0 }
    }
}

pub struct KillListIter<'a> {
    list: &'a KillList,
    i: usize,
}

impl<'a> Iterator for KillListIter<'a> {
    type Item = (usize, usize);
    fn next(&mut self) -> Option<(usize, usize)> {
        if self.i >= self.list.len() {
            return None;
        }
        let item = self.list.get(self.i);
        self.i += 1;
        Some(item)
    }
}

impl Default for KillList {
    fn default() -> KillList {
        KillList::new()
    }
}

impl PartialEq for KillList {
    fn eq(&self, other: &KillList) -> bool {
        self.len == other.len && self.iter().eq(other.iter())
    }
}

/// Equality against plain vectors keeps the historical test assertions.
impl PartialEq<Vec<(usize, usize)>> for KillList {
    fn eq(&self, other: &Vec<(usize, usize)>) -> bool {
        self.len == other.len() && self.iter().eq(other.iter().copied())
    }
}

pub struct KillListIntoIter {
    list: KillList,
    i: usize,
}

impl Iterator for KillListIntoIter {
    type Item = (usize, usize);
    fn next(&mut self) -> Option<(usize, usize)> {
        if self.i >= self.list.len() {
            return None;
        }
        let item = self.list.get(self.i);
        self.i += 1;
        Some(item)
    }
}

impl IntoIterator for KillList {
    type Item = (usize, usize);
    type IntoIter = KillListIntoIter;
    fn into_iter(self) -> KillListIntoIter {
        KillListIntoIter { list: self, i: 0 }
    }
}

impl<'a> IntoIterator for &'a KillList {
    type Item = (usize, usize);
    type IntoIter = KillListIter<'a>;
    fn into_iter(self) -> KillListIter<'a> {
        self.iter()
    }
}

/// What a policy wants done after a decode-step append.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Nothing to evict.
    Keep,
    /// Structured: drop this logical block entirely (table shuffle only).
    EvictBlock(usize),
    /// Unstructured: hole-punch these (logical block, offset) tokens.
    KillTokens(KillList),
}

/// `Send + Sync` so one policy instance can drive parallel episode
/// simulation; mutable scan scratch therefore lives in thread-local
/// storage (see `inverse_key_norm::SCAN_SCRATCH`), not in the policy.
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Structured policies only touch whole pages during decode.
    fn structured(&self) -> bool;

    /// Prompt compression: return the ASCENDING positions of tokens to
    /// retain, at most `budget` of them. `budget >= 1`; when
    /// `scores.len <= budget` every token must be kept.
    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize>;

    /// Decode-phase eviction decision after a token append. `budget` is the
    /// cache budget in tokens.
    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision;

    /// Tokens resident in the cache immediately after prefill for a prompt
    /// of `prompt_len` under `budget` — what policy-aware admission
    /// charges. The default is the budgeted pack; `FullCache` keeps the
    /// whole prompt regardless of budget and overrides accordingly.
    fn prefill_resident(&self, prompt_len: usize, budget: usize) -> usize {
        prompt_len.min(budget)
    }

    /// True when decode-phase decisions hole-punch tokens INSIDE existing
    /// pages ([`Decision::KillTokens`]) rather than dropping whole pages.
    /// Such in-place writes must never land on a shared (refcount > 1)
    /// page, so the scheduler copies-on-write every shared page these
    /// policies hold during round reservation — while it can still
    /// preempt on a dry arena (`DecodeBackend::prepare_round`). Any policy
    /// that can return `Decision::KillTokens` MUST override this to
    /// `true` (today: InverseKeyNorm, KeyDiff, and StreamingLLM, whose
    /// sliding window drains the oldest page token-by-token); policies
    /// that only ever RELEASE whole pages (`Decision::EvictBlock`) are
    /// refcount-safe without copies.
    fn kills_tokens(&self) -> bool {
        false
    }

    /// True when the policy consumes the per-step attention-feedback
    /// channel. Backends only assemble an [`AttnFeedback`] (an
    /// O(live-tokens) pass) for sequences whose policy asks for it, so
    /// attention-free policies keep their decode hot path byte-identical.
    /// Mirrored by `registry::PolicyInfo::wants_feedback` (the ROADMAP
    /// policy table's "feedback-consuming?" column).
    fn wants_feedback(&self) -> bool {
        false
    }

    /// Decode-phase decision with the backend's optional attention
    /// feedback. The default ignores the channel and defers to
    /// [`EvictionPolicy::post_append`], so attention-free policies and
    /// feedback-less backends (`None`) meet on the same code path;
    /// attention-guided policies override this and fall back to their
    /// proxy themselves when handed `None`.
    fn post_append_feedback(
        &self,
        cache: &SeqCache,
        budget: usize,
        _feedback: Option<&AttnFeedback>,
    ) -> Decision {
        self.post_append(cache, budget)
    }
}

/// The paper's comparable policy names in Fig. 2/3 order — the historical
/// sweep set. The full (growing) set, including the attention-feedback
/// policies, is [`registry::REGISTRY`].
pub const ALL_POLICIES: [&str; 5] =
    ["full", "streaming", "inverse_key_norm", "keydiff", "paged"];

// ---------------------------------------------------------------------------
// shared helpers for the policy impls
// ---------------------------------------------------------------------------

/// One live-token view row: (logical block, offset, position, [3]scores).
/// The scratch buffers the unstructured policies reuse across decode steps
/// hold these.
pub(crate) type LiveTok = (usize, usize, u32, [f32; 3]);

/// Shared O(n) selection core: the `k` best of `n` indices under `better`
/// (a TOTAL order over indices), returned ascending. Uses
/// `select_nth_unstable_by` instead of a full sort.
fn select_k_ascending<F>(n: usize, k: usize, mut better: F) -> Vec<usize>
where
    F: FnMut(&usize, &usize) -> std::cmp::Ordering,
{
    if k == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.select_nth_unstable_by(k - 1, &mut better);
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Indices of the `k` highest-scoring tokens, returned ASCENDING (on score
/// ties the earlier token wins, exactly like the former full-sort
/// implementation). `f32::total_cmp` keeps NaN from poisoning the
/// partition.
pub(crate) fn top_k_ascending(scores: &[f32], k: usize) -> Vec<usize> {
    // total order: score desc, then index asc
    select_k_ascending(scores.len(), k, |&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    })
}

/// Indices of the `k` LOWEST-scoring tokens, ascending. Direct O(n)
/// selection — no negated-copy allocation.
pub(crate) fn bottom_k_ascending(scores: &[f32], k: usize) -> Vec<usize> {
    // total order: score asc, then index asc
    select_k_ascending(scores.len(), k, |&a, &b| {
        scores[a].total_cmp(&scores[b]).then(a.cmp(&b))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SeqCache;
    use crate::util::propcheck;
    use crate::util::rng::Pcg32;

    fn mk_scores(vals: &[(f32, f32, f32)]) -> PrefillScores {
        PrefillScores {
            channels: [
                vals.iter().map(|v| v.0).collect(),
                vals.iter().map(|v| v.1).collect(),
                vals.iter().map(|v| v.2).collect(),
            ],
            len: vals.len(),
        }
    }

    #[test]
    fn from_graph_output_layer_mean() {
        // 3 channels x 2 layers x P=2
        let flat = vec![
            1.0, 2.0, /* c0 l0 */ 3.0, 4.0, /* c0 l1 */
            10.0, 20.0, /* c1 l0 */ 30.0, 40.0, /* c1 l1 */
            0.0, 0.0, /* c2 l0 */ 2.0, 2.0, /* c2 l1 */
        ];
        let s = PrefillScores::from_graph_output(&flat, 2, 2, 2);
        assert_eq!(s.channels[0], vec![2.0, 3.0]);
        assert_eq!(s.channels[1], vec![20.0, 30.0]);
        assert_eq!(s.channels[2], vec![1.0, 1.0]);
    }

    #[test]
    fn aggregate_decode() {
        let flat = vec![1.0, 3.0, 10.0, 30.0, 0.0, 4.0];
        assert_eq!(aggregate_decode_scores(&flat, 2), [2.0, 20.0, 2.0]);
    }

    #[test]
    fn top_k_stable_ascending() {
        let s = [5.0, 1.0, 5.0, 9.0];
        assert_eq!(top_k_ascending(&s, 2), vec![0, 3]);
        assert_eq!(top_k_ascending(&s, 3), vec![0, 2, 3]);
        assert_eq!(bottom_k_ascending(&s, 2), vec![0, 1]);
        assert_eq!(top_k_ascending(&s, 0), Vec::<usize>::new());
        assert_eq!(top_k_ascending(&s, 9), vec![0, 1, 2, 3]);
        assert_eq!(bottom_k_ascending(&s, 9), vec![0, 1, 2, 3]);
    }

    /// The O(n) selection must pick exactly the set the former full sort
    /// picked (score ties broken by earlier index).
    #[test]
    fn property_selection_matches_full_sort_reference() {
        fn reference_top_k(scores: &[f32], k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = (0..scores.len()).collect();
            idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
            let mut keep: Vec<usize> = idx.into_iter().take(k).collect();
            keep.sort_unstable();
            keep
        }
        propcheck::quick("topk-vs-sort", |rng: &mut Pcg32| {
            let n = 1 + rng.usize_below(200);
            let k = rng.usize_below(n + 4);
            // coarse grid so score ties actually occur
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(8) as f32) / 4.0).collect();
            if top_k_ascending(&scores, k) != reference_top_k(&scores, k) {
                return Err(format!("top_k mismatch n={n} k={k}"));
            }
            let neg: Vec<f32> = scores.iter().map(|s| -s).collect();
            if bottom_k_ascending(&scores, k) != reference_top_k(&neg, k) {
                return Err(format!("bottom_k mismatch n={n} k={k}"));
            }
            Ok(())
        });
    }

    #[test]
    fn kill_list_inline_and_spill() {
        let mut k = KillList::new();
        assert!(k.is_empty());
        for i in 0..(KillList::INLINE + 4) {
            k.push(i, i + 1);
        }
        assert_eq!(k.len(), KillList::INLINE + 4);
        for i in 0..k.len() {
            assert_eq!(k.get(i), (i, i + 1), "index {i} spans inline/spill");
        }
        let v: Vec<(usize, usize)> = (0..k.len()).map(|i| (i, i + 1)).collect();
        assert_eq!(k, v);
        let collected: Vec<(usize, usize)> = k.clone().into_iter().collect();
        assert_eq!(collected, v);
        let by_ref: Vec<(usize, usize)> = (&k).into_iter().collect();
        assert_eq!(by_ref, v);
        let mut other = KillList::new();
        other.push(0, 1);
        assert_ne!(k, other);
    }

    #[test]
    fn factory_known_and_unknown() {
        for info in REGISTRY {
            assert!(make_policy(info.name).is_ok(), "{}", info.name);
        }
        assert!(make_policy("h2o").is_err());
    }

    /// The historical sweep set stays a subset of the registry, in the
    /// same make_policy universe.
    #[test]
    fn all_policies_are_registered() {
        for n in ALL_POLICIES {
            assert!(registry::lookup(n).is_some(), "{n} missing from registry");
        }
    }

    /// A policy that ignores the feedback channel must behave identically
    /// through the defaulted feedback entry point.
    #[test]
    fn default_feedback_dispatch_defers_to_post_append() {
        let mut c = SeqCache::new(4, 6);
        let toks: Vec<(u32, [f32; 3])> =
            (0..12).map(|i| (i, [i as f32, i as f32, i as f32])).collect();
        c.load_prefill(&toks, 12);
        let fb = AttnFeedback { mass: vec![1.0; 12] };
        let p = make_policy("paged").unwrap();
        assert_eq!(p.post_append_feedback(&c, 8, Some(&fb)), p.post_append(&c, 8));
        assert_eq!(p.post_append_feedback(&c, 8, None), p.post_append(&c, 8));
    }

    /// Contract every registered policy must satisfy, checked against
    /// random prompts.
    #[test]
    fn property_prefill_keep_contract() {
        propcheck::quick("prefill-keep-contract", |rng: &mut Pcg32| {
            let len = 1 + rng.usize_below(300);
            let budget = 1 + rng.usize_below(320);
            let vals: Vec<(f32, f32, f32)> =
                (0..len).map(|_| (rng.f32(), rng.f32(), rng.f32())).collect();
            let scores = mk_scores(&vals);
            for info in REGISTRY {
                let name = info.name;
                let p = info.make();
                let keep = p.prefill_keep(&scores, budget);
                if len <= budget && keep.len() != len {
                    return Err(format!("{name}: must keep all under budget"));
                }
                if name != "full" && keep.len() > budget {
                    return Err(format!("{name}: keep {} > budget {budget}", keep.len()));
                }
                let mut sorted = keep.clone();
                sorted.sort_unstable();
                sorted.dedup();
                if sorted != keep {
                    return Err(format!("{name}: keep not ascending/unique"));
                }
                if keep.iter().any(|&i| i >= len) {
                    return Err(format!("{name}: keep index out of range"));
                }
            }
            Ok(())
        });
    }

    /// Decode contract: run random decode streams through every registered
    /// policy — dispatching through the feedback entry point, alternating
    /// a synthetic mass vector with `None` for feedback-consuming policies
    /// so both the guided path and the proxy fallback are exercised — and
    /// check budget adherence and invariants.
    #[test]
    fn property_decode_budget_adherence() {
        propcheck::quick("decode-budget", |rng: &mut Pcg32| {
            let bs = *rng.choose(&[4usize, 8, 16]);
            let budget_blocks = 2 + rng.usize_below(4);
            let budget = budget_blocks * bs;
            for info in REGISTRY {
                let name = info.name;
                if name == "full" {
                    continue; // unbounded by design
                }
                let p = info.make();
                let cap = budget_blocks + 3;
                let mut c = SeqCache::new(bs, cap);
                let pre: Vec<(u32, [f32; 3])> =
                    (0..budget as u32).map(|i| (i, [rng.f32(), rng.f32(), rng.f32()])).collect();
                c.load_prefill(&pre, budget as u32);
                for step in 0..(4 * bs) {
                    // Token-killing policies fragment pages and
                    // legitimately hold more physical blocks than the
                    // token budget implies (the paper's Limitation 1/2);
                    // the runtime grows the bucket. Whole-page-only
                    // structured policies must never need that.
                    if !c.ensure_block() {
                        if info.structured && !info.kills_tokens {
                            return Err(format!("{name}: pool exhausted (no eviction?)"));
                        }
                        c.grow(c.capacity_blocks() + 2);
                        assert!(c.ensure_block());
                    }
                    c.append([rng.f32(), rng.f32(), rng.f32()]);
                    let fb = (info.wants_feedback && step % 2 == 0).then(|| AttnFeedback {
                        mass: (0..c.next_position()).map(|_| rng.f32()).collect(),
                    });
                    match p.post_append_feedback(&c, budget, fb.as_ref()) {
                        Decision::Keep => {}
                        Decision::EvictBlock(i) => {
                            if i + 1 >= c.n_blocks() {
                                return Err(format!("{name}: evicted newest block"));
                            }
                            c.evict_block(i);
                        }
                        Decision::KillTokens(ts) => {
                            for (bi, off) in ts {
                                c.kill_token(bi, off);
                            }
                        }
                    }
                    c.check_invariants()?;
                    // allow one page of slack over the budget (paper: evict
                    // when the newest block fills)
                    if c.live_tokens() > budget + bs {
                        return Err(format!(
                            "{name}: live {} exceeds budget {budget} + B {bs}",
                            c.live_tokens()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
