//! KeyDiff baseline (Park et al. 2025): evict tokens whose keys are most
//! similar to the rest of the cache (cosine similarity to the mean-key
//! anchor), preserving a geometrically diverse key set. Unstructured, like
//! InverseKeyNorm: per-step global scans + token-level hole punching.

use super::inverse_key_norm::unstructured_evict_worst;
use super::{bottom_k_ascending, Decision, EvictionPolicy, PrefillScores, CH_KEYDIFF};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone, Default)]
pub struct KeyDiff;

impl EvictionPolicy for KeyDiff {
    fn name(&self) -> &'static str {
        "keydiff"
    }

    fn structured(&self) -> bool {
        false
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        if scores.len <= budget {
            return (0..scores.len).collect();
        }
        // keep the least anchor-similar (most diverse) keys
        bottom_k_ascending(&scores.channels[CH_KEYDIFF], budget)
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        // highest cosine = most redundant = evict first
        unstructured_evict_worst(cache, budget, CH_KEYDIFF, true)
    }

    /// Hole-punches tokens inside pages: shared prefix pages must be
    /// copied-on-write before this policy's decode decisions run.
    fn kills_tokens(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_keeps_diverse_keys() {
        let s = PrefillScores {
            channels: [
                vec![0.0; 4],
                vec![0.0; 4],
                vec![0.99, 0.10, 0.80, -0.30],
            ],
            len: 4,
        };
        let p = KeyDiff::default();
        assert_eq!(p.prefill_keep(&s, 2), vec![1, 3]);
    }

    #[test]
    fn decode_kills_most_redundant() {
        let p = KeyDiff::default();
        let mut c = SeqCache::new(4, 4);
        let cos = [0.1f32, 0.95, 0.3, 0.2];
        let toks: Vec<(u32, [f32; 3])> =
            cos.iter().enumerate().map(|(i, &v)| (i as u32, [0.0, 0.0, v])).collect();
        c.load_prefill(&toks, 4);
        c.ensure_block();
        c.append([0.0, 0.0, 0.0]);
        match p.post_append(&c, 4) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(0, 1)]),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn under_budget_keeps() {
        let p = KeyDiff::default();
        let mut c = SeqCache::new(4, 2);
        c.load_prefill(&[(0, [0.0; 3])], 1);
        assert_eq!(p.post_append(&c, 4), Decision::Keep);
    }
}
