//! StreamingLLM baseline (Xiao et al. 2023) as described in the paper's
//! §5.2 and Appendix A.1: a handful of initial tokens are pinned as
//! "attention sinks"; the rest of the cache is a sliding window over the
//! most recent tokens. During decode one token is evicted per step (the
//! oldest non-sink), so the oldest block drains token-by-token and is only
//! freed once empty — cheap to decide, but it touches the cache metadata
//! every single step (the overhead the paper contrasts with PagedEviction).

use super::{Decision, EvictionPolicy, KillList, PrefillScores};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone)]
pub struct StreamingLlm {
    /// Number of initial-position tokens pinned forever (paper: "e.g. the
    /// first 4 tokens").
    pub sinks: usize,
}

impl Default for StreamingLlm {
    fn default() -> Self {
        StreamingLlm { sinks: 4 }
    }
}

impl EvictionPolicy for StreamingLlm {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn structured(&self) -> bool {
        // Structured in the paper's taxonomy: evictions stay within one
        // block (the oldest), no global score scans.
        true
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        let len = scores.len;
        if len <= budget {
            return (0..len).collect();
        }
        let sinks = self.sinks.min(budget);
        let window = budget - sinks;
        let mut keep: Vec<usize> = (0..sinks).collect();
        keep.extend(len - window..len);
        keep
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        if cache.live_tokens() <= budget {
            return Decision::Keep;
        }
        // Evict the oldest live non-sink token (one per step — recency
        // order, not scores).
        let mut kills = KillList::new();
        let mut over = cache.live_tokens() - budget;
        'outer: for (bi, blk) in cache.blocks().iter().enumerate() {
            for (off, pos, _) in blk.live_tokens() {
                if (pos as usize) < self.sinks {
                    continue; // pinned sink
                }
                kills.push(bi, off);
                over -= 1;
                if over == 0 {
                    break 'outer;
                }
            }
        }
        if kills.is_empty() {
            Decision::Keep
        } else {
            Decision::KillTokens(kills)
        }
    }

    /// Structured in the paper's taxonomy, but the sliding window is
    /// maintained by killing the oldest non-sink token IN PLACE — so
    /// shared prefix pages must be copied-on-write before its decode
    /// decisions run, exactly like the unstructured baselines.
    fn kills_tokens(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores(len: usize) -> PrefillScores {
        PrefillScores {
            channels: [vec![0.0; len], vec![0.0; len], vec![0.0; len]],
            len,
        }
    }

    #[test]
    fn prefill_sinks_plus_window() {
        let p = StreamingLlm::default();
        let keep = p.prefill_keep(&scores(20), 10);
        assert_eq!(keep, vec![0, 1, 2, 3, 14, 15, 16, 17, 18, 19]);
    }

    #[test]
    fn prefill_tiny_budget_all_sinks() {
        let p = StreamingLlm::default();
        let keep = p.prefill_keep(&scores(20), 3);
        assert_eq!(keep, vec![0, 1, 2]);
    }

    #[test]
    fn decode_evicts_oldest_non_sink() {
        let p = StreamingLlm::default();
        let bs = 4;
        let mut c = SeqCache::new(bs, 6);
        // positions 0..12 (tokens 0-3 include the 4 sinks)
        let toks: Vec<(u32, [f32; 3])> = (0..12).map(|i| (i, [0.0; 3])).collect();
        c.load_prefill(&toks, 12);
        c.ensure_block();
        c.append([0.0; 3]); // live = 13 > budget = 12
        match p.post_append(&c, 12) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(1, 0)]), // pos 4
            d => panic!("expected kill, got {d:?}"),
        }
    }

    #[test]
    fn sinks_survive_long_generation() {
        let p = StreamingLlm::default();
        let bs = 4;
        let budget = 8;
        let mut c = SeqCache::new(bs, 8);
        c.load_prefill(&(0..8).map(|i| (i, [0.0; 3])).collect::<Vec<_>>(), 8);
        for _ in 0..30 {
            assert!(c.ensure_block());
            c.append([0.0; 3]);
            if let Decision::KillTokens(ts) = p.post_append(&c, budget) {
                for (bi, off) in ts {
                    c.kill_token(bi, off);
                }
            }
            c.check_invariants().unwrap();
            assert_eq!(c.live_tokens(), budget.min(c.live_tokens()));
        }
        // all 4 sink positions still live
        let live_pos: Vec<u32> =
            c.live_token_list().iter().map(|&(_, _, p, _)| p).collect();
        for s in 0..4 {
            assert!(live_pos.contains(&s), "sink {s} evicted");
        }
        // and it fragments the sink block (paper Fig. 5 shape)
        assert!(c.partial_blocks() >= 1);
    }

    #[test]
    fn per_step_mask_updates_counted() {
        // StreamingLLM must touch the cache every step once saturated —
        // the overhead PagedEviction avoids.
        let p = StreamingLlm::default();
        let bs = 4;
        let budget = 8;
        let mut c = SeqCache::new(bs, 8);
        c.load_prefill(&(0..8).map(|i| (i, [0.0; 3])).collect::<Vec<_>>(), 8);
        let steps = 20;
        for _ in 0..steps {
            c.ensure_block();
            c.append([0.0; 3]);
            if let Decision::KillTokens(ts) = p.post_append(&c, budget) {
                for (bi, off) in ts {
                    c.kill_token(bi, off);
                }
            }
        }
        assert!(c.stats.mask_updates >= steps as u64);
    }
}
