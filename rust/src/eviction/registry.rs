//! Single source of truth for eviction-policy names.
//!
//! Every surface that parses a policy name (CLI `--policy`, trace
//! `policy=`, wire JSON) or enumerates the zoo (fig2 sweeps, the
//! policy-contract property suite, the autotuner's decision table) goes
//! through this table. Adding a policy here is the ONE step that lights it
//! up everywhere, and an unknown name errors with the full valid set
//! instead of whichever subset a local `match` remembered.

use super::auto::AUTO_POLICY;
use super::{
    AttentionGate, EvictionPolicy, FullCache, InverseKeyNorm, KeyDiff, PagedEviction,
    SelfAttnGuided, StreamingLlm,
};

/// One registry row: canonical name, accepted aliases, the contract flags
/// the policy instance must agree with (pinned by `registry_matches_impls`)
/// and its constructor.
pub struct PolicyInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Whole-page decode evictions only (the paper's taxonomy).
    pub structured: bool,
    /// Decode decisions hole-punch tokens inside pages — shared prefix
    /// pages must be copied-on-write before they run.
    pub kills_tokens: bool,
    /// Consumes the per-step attention-feedback channel when the backend
    /// supplies one (falls back to the score-channel proxy otherwise).
    pub wants_feedback: bool,
    ctor: fn() -> Box<dyn EvictionPolicy>,
}

impl PolicyInfo {
    /// Instantiate this row's policy.
    pub fn make(&self) -> Box<dyn EvictionPolicy> {
        (self.ctor)()
    }

    /// Whether `name` is this row's canonical name or one of its aliases.
    pub fn answers_to(&self, name: &str) -> bool {
        self.name == name || self.aliases.contains(&name)
    }
}

/// Every registered policy: the paper's baselines first (Fig. 2/3 order,
/// mirrored by [`super::ALL_POLICIES`]), then the attention-feedback
/// generation.
pub static REGISTRY: &[PolicyInfo] = &[
    PolicyInfo {
        name: "full",
        aliases: &["full_cache"],
        structured: true,
        kills_tokens: false,
        wants_feedback: false,
        ctor: || Box::new(FullCache),
    },
    PolicyInfo {
        name: "streaming",
        aliases: &["streaming_llm"],
        structured: true,
        kills_tokens: true,
        wants_feedback: false,
        ctor: || Box::new(StreamingLlm::default()),
    },
    PolicyInfo {
        name: "inverse_key_norm",
        aliases: &["key_norm", "l2"],
        structured: false,
        kills_tokens: true,
        wants_feedback: false,
        ctor: || Box::new(InverseKeyNorm::default()),
    },
    PolicyInfo {
        name: "keydiff",
        aliases: &["key_diff"],
        structured: false,
        kills_tokens: true,
        wants_feedback: false,
        ctor: || Box::new(KeyDiff::default()),
    },
    PolicyInfo {
        name: "paged",
        aliases: &["paged_eviction"],
        structured: true,
        kills_tokens: false,
        wants_feedback: false,
        ctor: || Box::new(PagedEviction::default()),
    },
    PolicyInfo {
        name: "self_attn",
        aliases: &["self_attn_guided"],
        structured: true,
        kills_tokens: false,
        wants_feedback: true,
        ctor: || Box::new(SelfAttnGuided::default()),
    },
    PolicyInfo {
        name: "self_attn_token",
        aliases: &[],
        structured: false,
        kills_tokens: true,
        wants_feedback: true,
        ctor: || Box::new(SelfAttnGuided::token_level()),
    },
    PolicyInfo {
        name: "attention_gate",
        aliases: &["attn_gate"],
        structured: true,
        kills_tokens: false,
        wants_feedback: true,
        ctor: || Box::new(AttentionGate::default()),
    },
];

/// Look up a registry row by canonical name or alias.
pub fn lookup(name: &str) -> Option<&'static PolicyInfo> {
    REGISTRY.iter().find(|p| p.answers_to(name))
}

/// Comma-joined canonical names — the "valid set" error surfaces print.
pub fn valid_names() -> String {
    REGISTRY.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
}

/// Instantiate a policy by its CLI/bench/wire name.
pub fn make_policy(name: &str) -> anyhow::Result<Box<dyn EvictionPolicy>> {
    match lookup(name) {
        Some(info) => Ok(info.make()),
        None => anyhow::bail!("unknown eviction policy {name:?} (valid: {})", valid_names()),
    }
}

/// Validate a REQUEST-level policy name: any registry name/alias, or the
/// autotuner sentinel `"auto"`, which the scheduler resolves to a concrete
/// registry entry at submit time (see `scheduler::autotune`). Request
/// ingress points (session submit, engine submit, wire parse, trace parse)
/// use this instead of [`make_policy`] so `"auto"` is admitted without
/// being instantiable.
pub fn validate_request_policy(name: &str) -> anyhow::Result<()> {
    if name == AUTO_POLICY || lookup(name).is_some() {
        return Ok(());
    }
    anyhow::bail!(
        "unknown eviction policy {name:?} (valid: {}, or {AUTO_POLICY:?})",
        valid_names()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The table's flags are contracts: the instance each row builds must
    /// agree with them, or admission/CoW decisions made from the table
    /// diverge from what the policy actually does.
    #[test]
    fn registry_matches_impls() {
        for info in REGISTRY {
            let p = info.make();
            assert_eq!(p.name(), info.name, "canonical name");
            assert_eq!(p.structured(), info.structured, "{}: structured", info.name);
            assert_eq!(p.kills_tokens(), info.kills_tokens, "{}: kills_tokens", info.name);
            assert_eq!(p.wants_feedback(), info.wants_feedback, "{}: wants_feedback", info.name);
        }
    }

    #[test]
    fn names_and_aliases_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for info in REGISTRY {
            assert!(seen.insert(info.name), "duplicate name {}", info.name);
            for a in info.aliases {
                assert!(seen.insert(a), "duplicate alias {a}");
            }
        }
        assert!(!seen.contains(AUTO_POLICY), "\"auto\" must stay a sentinel");
    }

    #[test]
    fn aliases_resolve_to_their_row() {
        for info in REGISTRY {
            for a in info.aliases {
                assert_eq!(lookup(a).map(|p| p.name), Some(info.name), "alias {a}");
                assert_eq!(make_policy(a).unwrap().name(), info.name, "alias {a}");
            }
        }
    }

    #[test]
    fn unknown_name_lists_the_valid_set() {
        let err = make_policy("h2o").unwrap_err().to_string();
        for info in REGISTRY {
            assert!(err.contains(info.name), "error must list {}: {err}", info.name);
        }
    }

    #[test]
    fn request_validation_accepts_auto() {
        assert!(validate_request_policy(AUTO_POLICY).is_ok());
        for info in REGISTRY {
            assert!(validate_request_policy(info.name).is_ok(), "{}", info.name);
            for a in info.aliases {
                assert!(validate_request_policy(a).is_ok(), "{a}");
            }
        }
        let err = validate_request_policy("h2o").unwrap_err().to_string();
        assert!(err.contains("auto"), "error must mention the sentinel: {err}");
    }
}
