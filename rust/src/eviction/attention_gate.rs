//! AttentionGate — in-context gating ("In-context KV-Cache Eviction via
//! Attention-Gate", arXiv 2410.12876): every candidate page carries a gate
//! statistic — its recent attention RATE, i.e. accumulated mass normalised
//! by how long the position has been resident — and on the block-full
//! trigger the OLDEST page whose gate falls below `threshold ×` the mean
//! candidate gate is dropped: a page the context has stopped attending to
//! is evicted even when its lifetime total still looks respectable. When
//! every page passes the gate, the global minimum goes (the memory bound
//! always wins — the budget is hard).
//!
//! Structured and CoW-free: only whole pages are ever released. Without a
//! backend feedback channel the gate runs on the V/K-ratio proxy, the same
//! graceful degradation as [`super::SelfAttnGuided`].

use super::{top_k_ascending, AttnFeedback, Decision, EvictionPolicy, PrefillScores, CH_VK_RATIO};
use crate::kvcache::{Block, SeqCache};

#[derive(Debug, Clone)]
pub struct AttentionGate {
    /// Never evict the most recent blocks (newest always protected).
    pub protect_recent_blocks: usize,
    /// A page passes the gate while its score stays at or above
    /// `threshold ×` the mean candidate gate score.
    pub threshold: f32,
}

impl Default for AttentionGate {
    fn default() -> Self {
        AttentionGate { protect_recent_blocks: 1, threshold: 0.75 }
    }
}

impl AttentionGate {
    /// Mean gate score of one page: attention mass per resident step with
    /// feedback, the V/K-ratio proxy without. Zero-allocation; called once
    /// per candidate per pass.
    fn gate_score(&self, b: &Block, horizon: u32, fb: Option<&AttnFeedback>) -> f64 {
        let (mut sum, mut cnt) = (0.0f64, 0u32);
        for (_, pos, sc) in b.live_tokens() {
            let g = match fb {
                Some(f) => {
                    let age = horizon.saturating_sub(pos).max(1);
                    f64::from(f.mass_at(pos as usize)) / f64::from(age)
                }
                None => f64::from(sc[CH_VK_RATIO]),
            };
            sum += g;
            cnt += 1;
        }
        if cnt == 0 {
            0.0
        } else {
            sum / f64::from(cnt)
        }
    }

    fn decide(&self, cache: &SeqCache, budget: usize, fb: Option<&AttnFeedback>) -> Decision {
        // Same trigger as the paper's structured decode path: act only
        // when the newest block just filled and the budget is exceeded.
        if !cache.last_block_full() || cache.live_tokens() <= budget {
            return Decision::Keep;
        }
        let n = cache.n_blocks();
        let protected = self.protect_recent_blocks.max(1);
        if n <= protected {
            return Decision::Keep;
        }
        let fb = fb.filter(|f| !f.is_empty());
        let horizon = cache.next_position();
        let candidates = &cache.blocks()[..n - protected];
        // pass 1: the gate bar (mean over candidates); pass 2: the oldest
        // failing page, tracking the global minimum as the all-pass
        // fallback. Two cheap scans instead of a score buffer keeps the
        // decode decision path allocation-free.
        let mean: f64 = candidates.iter().map(|b| self.gate_score(b, horizon, fb)).sum::<f64>()
            / candidates.len() as f64;
        let bar = f64::from(self.threshold) * mean;
        let (mut min_i, mut min_g) = (0usize, f64::INFINITY);
        for (i, b) in candidates.iter().enumerate() {
            let g = self.gate_score(b, horizon, fb);
            if g < bar {
                return Decision::EvictBlock(i); // oldest gated-out page
            }
            if g < min_g {
                min_g = g;
                min_i = i;
            }
        }
        Decision::EvictBlock(min_i)
    }
}

impl EvictionPolicy for AttentionGate {
    fn name(&self) -> &'static str {
        "attention_gate"
    }

    fn structured(&self) -> bool {
        true
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        // No in-context statistics exist before decode: proxy top-k.
        if scores.len <= budget {
            return (0..scores.len).collect();
        }
        top_k_ascending(&scores.channels[CH_VK_RATIO], budget)
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        self.decide(cache, budget, None)
    }

    fn post_append_feedback(
        &self,
        cache: &SeqCache,
        budget: usize,
        feedback: Option<&AttnFeedback>,
    ) -> Decision {
        self.decide(cache, budget, feedback)
    }

    fn wants_feedback(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_blocks(block_scores: &[f32], bs: usize) -> SeqCache {
        let mut c = SeqCache::new(bs, block_scores.len() + 2);
        let toks: Vec<(u32, [f32; 3])> = block_scores
            .iter()
            .flat_map(|&s| std::iter::repeat([s, s, s]).take(bs))
            .enumerate()
            .map(|(i, sc)| (i as u32, sc))
            .collect();
        let n = toks.len() as u32;
        c.load_prefill(&toks, n);
        c
    }

    fn fb_from(mass: &[f32]) -> AttnFeedback {
        AttnFeedback { mass: mass.to_vec() }
    }

    #[test]
    fn under_budget_or_partial_block_keeps() {
        let bs = 4;
        let p = AttentionGate::default();
        let mut c = cache_with_blocks(&[0.5, 0.5], bs);
        assert_eq!(p.post_append(&c, 2 * bs), Decision::Keep);
        c.ensure_block();
        c.append([0.5; 3]); // newest block partial
        assert_eq!(p.post_append(&c, bs), Decision::Keep);
    }

    #[test]
    fn gate_evicts_oldest_starved_page() {
        let bs = 4;
        let c = cache_with_blocks(&[0.5, 0.5, 0.5, 0.5], bs);
        let p = AttentionGate::default();
        // blocks 1 and 2 both starved (fail the gate); block 1 is older
        let mut mass = vec![1.0f32; 4 * bs];
        for m in &mut mass[bs..3 * bs] {
            *m = 0.0;
        }
        assert_eq!(
            p.post_append_feedback(&c, 2 * bs, Some(&fb_from(&mass))),
            Decision::EvictBlock(1)
        );
    }

    #[test]
    fn all_pass_falls_back_to_minimum() {
        let bs = 4;
        let c = cache_with_blocks(&[0.5, 0.5, 0.5], bs);
        // block 0 is older, so matching RATES needs more accumulated
        // mass: 1.6/token over ages 9..=12 lands just under young block
        // 1's rate — both pass the gate, and the (slight) minimum, block
        // 0, goes anyway; the budget still binds
        let p = AttentionGate::default();
        let mut mass = vec![1.0f32; 3 * bs];
        for m in &mut mass[..bs] {
            *m = 1.6;
        }
        assert_eq!(
            p.post_append_feedback(&c, bs, Some(&fb_from(&mass))),
            Decision::EvictBlock(0)
        );
    }

    #[test]
    fn proxy_fallback_gates_on_vk_ratio() {
        let bs = 4;
        // block 1's proxy collapses vs its peers -> gated out without fb
        let c = cache_with_blocks(&[0.8, 0.05, 0.9], bs);
        let p = AttentionGate::default();
        assert_eq!(p.post_append(&c, bs), Decision::EvictBlock(1));
        assert_eq!(p.post_append_feedback(&c, bs, None), Decision::EvictBlock(1));
    }

    #[test]
    fn newest_block_always_protected() {
        let bs = 4;
        let c = cache_with_blocks(&[0.5, 0.5], bs);
        let p = AttentionGate::default();
        // only candidate is block 0 whatever the mass says
        let mass = vec![1.0f32; 2 * bs];
        assert_eq!(
            p.post_append_feedback(&c, bs, Some(&fb_from(&mass))),
            Decision::EvictBlock(0)
        );
    }

    #[test]
    fn recency_rate_beats_lifetime_total() {
        let bs = 4;
        let c = cache_with_blocks(&[0.5, 0.5, 0.5], bs);
        let p = AttentionGate::default();
        // Block 0 accumulated a big TOTAL over a long residence, but its
        // per-step rate is lower than young block 1's: with horizon 12,
        // ages are ~12-8 (block 0) vs ~8-4 (block 1). Give block 0 total
        // 1.0/token (rate ~1/10) and block 1 total 2.0/token (rate ~1/3):
        // block 0 fails the gate first.
        let mut mass = vec![2.0f32; 3 * bs];
        for m in &mut mass[..bs] {
            *m = 1.0;
        }
        assert_eq!(
            p.post_append_feedback(&c, bs, Some(&fb_from(&mass))),
            Decision::EvictBlock(0)
        );
    }
}
