//! SelfAttnGuided — self-attention-guided eviction ("LLMs Know What to
//! Drop", arXiv 2503.08879): rank entries by the ACCUMULATED attention
//! mass each position has received and evict the least-attended. The mass
//! arrives through the backend's optional per-step feedback channel
//! ([`AttnFeedback`]); a backend that cannot supply one (the PJRT path —
//! no kernel modifications, exactly the paper's constraint) hands the
//! policy `None` and it falls back to the attention-free V/K-ratio proxy,
//! degrading gracefully to PagedEviction-shaped behaviour.
//!
//! Two variants share the struct, selected by `block_wise`:
//!
//!   * **structured** (`"self_attn"`, default): on the block-full trigger,
//!     evict the whole page with the lowest mean accumulated mass —
//!     table-shuffle-only decode overhead, CoW-free;
//!   * **token-level** (`"self_attn_token"`): kill the globally
//!     least-attended tokens one by one, fragmenting pages like the other
//!     unstructured baselines (and requiring CoW on shared pages).

use std::cell::RefCell;

use super::inverse_key_norm::unstructured_evict_worst;
use super::{
    top_k_ascending, AttnFeedback, Decision, EvictionPolicy, KillList, LiveTok, PrefillScores,
    CH_VK_RATIO,
};
use crate::kvcache::SeqCache;

#[derive(Debug, Clone)]
pub struct SelfAttnGuided {
    /// Structured variant: decode evictions drop whole least-attended
    /// pages. `false` = token-level kills.
    pub block_wise: bool,
    /// Never evict the most recent blocks (the newest is always
    /// protected): their attention mass is still accumulating, so ranking
    /// them against settled pages would systematically drop fresh context.
    pub protect_recent_blocks: usize,
}

impl SelfAttnGuided {
    /// The token-level (`"self_attn_token"`) variant.
    pub fn token_level() -> Self {
        SelfAttnGuided { block_wise: false, protect_recent_blocks: 1 }
    }
}

impl Default for SelfAttnGuided {
    fn default() -> Self {
        SelfAttnGuided { block_wise: true, protect_recent_blocks: 1 }
    }
}

thread_local! {
    /// Per-thread live-token scan buffer for the token-level variant —
    /// same zero-allocation discipline as the unstructured baselines'
    /// `SCAN_SCRATCH`.
    static MASS_SCRATCH: RefCell<Vec<LiveTok>> = RefCell::new(Vec::new());
}

impl SelfAttnGuided {
    /// Structured feedback path: evict the page with the lowest mean
    /// accumulated attention mass (paper Alg. 3 trigger, mass-ranked).
    fn evict_block_by_mass(&self, cache: &SeqCache, budget: usize, fb: &AttnFeedback) -> Decision {
        if !cache.last_block_full() || cache.live_tokens() <= budget {
            return Decision::Keep;
        }
        let n = cache.n_blocks();
        let protected = self.protect_recent_blocks.max(1);
        if n <= protected {
            return Decision::Keep;
        }
        let pick = cache.blocks()[..n - protected]
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let (mut sum, mut cnt) = (0.0f64, 0u32);
                for (_, pos, _) in b.live_tokens() {
                    sum += f64::from(fb.mass_at(pos as usize));
                    cnt += 1;
                }
                (i, if cnt == 0 { 0.0 } else { sum / f64::from(cnt) })
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(i, _)| i);
        match pick {
            Some(i) => Decision::EvictBlock(i),
            None => Decision::Keep,
        }
    }

    /// Token-level feedback path: kill the globally least-attended live
    /// tokens (excluding the just-appended one) until within budget.
    fn kill_tokens_by_mass(&self, cache: &SeqCache, budget: usize, fb: &AttnFeedback) -> Decision {
        let live = cache.live_tokens();
        if live <= budget {
            return Decision::Keep;
        }
        let newest_pos = cache.next_position().saturating_sub(1);
        MASS_SCRATCH.with(|scratch| {
            let mut tokens = scratch.borrow_mut();
            cache.collect_live_tokens(&mut tokens);
            tokens.retain(|&(_, _, pos, _)| pos != newest_pos);
            let over = (live - budget).min(tokens.len());
            if over == 0 {
                return Decision::Keep;
            }
            // least-attended first; (block, offset) tie-break keeps the
            // kill set fully deterministic even under equal mass
            let cmp = |a: &LiveTok, b: &LiveTok| {
                let (ma, mb) = (fb.mass_at(a.2 as usize), fb.mass_at(b.2 as usize));
                ma.total_cmp(&mb).then_with(|| (a.0, a.1).cmp(&(b.0, b.1)))
            };
            if over < tokens.len() {
                tokens.select_nth_unstable_by(over - 1, cmp);
            }
            tokens[..over].sort_unstable_by(cmp);
            let mut kills = KillList::new();
            for &(bi, off, _, _) in &tokens[..over] {
                kills.push(bi, off);
            }
            Decision::KillTokens(kills)
        })
    }

    /// Proxy fallback for the structured variant — the V/K-ratio stands in
    /// for attention mass, which is exactly PagedEviction's pick.
    fn evict_block_by_proxy(&self, cache: &SeqCache, budget: usize) -> Decision {
        if !cache.last_block_full() || cache.live_tokens() <= budget {
            return Decision::Keep;
        }
        let n = cache.n_blocks();
        let protected = self.protect_recent_blocks.max(1);
        if n <= protected {
            return Decision::Keep;
        }
        let pick = cache.blocks()[..n - protected]
            .iter()
            .enumerate()
            .map(|(i, b)| (i, b.mean_score(CH_VK_RATIO)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(i, _)| i);
        match pick {
            Some(i) => Decision::EvictBlock(i),
            None => Decision::Keep,
        }
    }
}

impl EvictionPolicy for SelfAttnGuided {
    fn name(&self) -> &'static str {
        if self.block_wise {
            "self_attn"
        } else {
            "self_attn_token"
        }
    }

    fn structured(&self) -> bool {
        self.block_wise
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        // No decode feedback exists yet at prefill time: keep the
        // highest-proxy tokens, like the paper's method.
        if scores.len <= budget {
            return (0..scores.len).collect();
        }
        top_k_ascending(&scores.channels[CH_VK_RATIO], budget)
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        if self.block_wise {
            self.evict_block_by_proxy(cache, budget)
        } else {
            unstructured_evict_worst(cache, budget, CH_VK_RATIO, /*higher_is_worse=*/ false)
        }
    }

    fn post_append_feedback(
        &self,
        cache: &SeqCache,
        budget: usize,
        feedback: Option<&AttnFeedback>,
    ) -> Decision {
        match feedback {
            Some(fb) if !fb.is_empty() => {
                if self.block_wise {
                    self.evict_block_by_mass(cache, budget, fb)
                } else {
                    self.kill_tokens_by_mass(cache, budget, fb)
                }
            }
            _ => self.post_append(cache, budget),
        }
    }

    fn kills_tokens(&self) -> bool {
        !self.block_wise
    }

    fn wants_feedback(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One cache with `block_scores.len()` full blocks; every token of
    /// block `i` carries proxy score `block_scores[i]` on all channels.
    fn cache_with_blocks(block_scores: &[f32], bs: usize) -> SeqCache {
        let mut c = SeqCache::new(bs, block_scores.len() + 2);
        let toks: Vec<(u32, [f32; 3])> = block_scores
            .iter()
            .flat_map(|&s| std::iter::repeat([s, s, s]).take(bs))
            .enumerate()
            .map(|(i, sc)| (i as u32, sc))
            .collect();
        let n = toks.len() as u32;
        c.load_prefill(&toks, n);
        c
    }

    fn fb_from(mass: &[f32]) -> AttnFeedback {
        AttnFeedback { mass: mass.to_vec() }
    }

    #[test]
    fn structured_feedback_overrides_proxy() {
        let bs = 4;
        // proxy says block 0 is worst (0.1); feedback says block 1 is
        let c = cache_with_blocks(&[0.1, 0.9, 0.5], bs);
        let p = SelfAttnGuided::default();
        let mut mass = vec![1.0f32; 3 * bs];
        for m in &mut mass[bs..2 * bs] {
            *m = 0.01; // block 1 barely attended
        }
        assert_eq!(
            p.post_append_feedback(&c, 2 * bs, Some(&fb_from(&mass))),
            Decision::EvictBlock(1)
        );
        // without feedback the proxy pick wins
        assert_eq!(p.post_append_feedback(&c, 2 * bs, None), Decision::EvictBlock(0));
        assert_eq!(p.post_append(&c, 2 * bs), Decision::EvictBlock(0));
    }

    #[test]
    fn structured_protects_recent_and_waits_for_full_block() {
        let bs = 4;
        let mut c = cache_with_blocks(&[0.5, 0.5], bs);
        let p = SelfAttnGuided::default();
        let mass = vec![1.0f32; 3 * bs];
        // newest block partially filled -> Keep even over budget
        c.ensure_block();
        c.append([0.5; 3]);
        assert_eq!(p.post_append_feedback(&c, bs, Some(&fb_from(&mass))), Decision::Keep);
        // fill it; lowest-mass block is the newest -> must evict an older one
        for _ in 0..bs - 1 {
            c.ensure_block();
            c.append([0.5; 3]);
        }
        let mut mass = vec![1.0f32; 3 * bs];
        for m in &mut mass[2 * bs..] {
            *m = 0.0; // newest block least attended — but protected
        }
        mass[bs] = 0.5; // block 1 second-least
        match p.post_append_feedback(&c, bs, Some(&fb_from(&mass))) {
            Decision::EvictBlock(i) => assert!(i < 2, "newest block must stay"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn token_level_kills_least_attended_not_newest() {
        let bs = 4;
        let mut c = cache_with_blocks(&[0.5, 0.5], bs);
        let p = SelfAttnGuided::token_level();
        assert!(!p.structured());
        assert!(p.kills_tokens());
        c.ensure_block();
        c.append([0.5; 3]); // position 8, the newest
        // newest position has the least mass but must survive; next-least
        // is position 2 (block 0, offset 2)
        let mut mass = vec![1.0f32; 9];
        mass[8] = 0.0;
        mass[2] = 0.1;
        match p.post_append_feedback(&c, 8, Some(&fb_from(&mass))) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(0, 2)]),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn token_level_proxy_fallback_kills_lowest_ratio() {
        let p = SelfAttnGuided::token_level();
        let bs = 4;
        let mut c = SeqCache::new(bs, 4);
        // V/K ratios 1..=8: token 0 (ratio 1) is the least important
        let toks: Vec<(u32, [f32; 3])> =
            (0..8).map(|i| (i, [(i + 1) as f32, 0.0, 0.0])).collect();
        c.load_prefill(&toks, 8);
        c.ensure_block();
        c.append([9.0, 0.0, 0.0]);
        match p.post_append_feedback(&c, 8, None) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(0, 0)]),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn under_budget_keeps() {
        let c = cache_with_blocks(&[0.5, 0.5], 4);
        let fb = fb_from(&[0.0; 8]);
        for p in [SelfAttnGuided::default(), SelfAttnGuided::token_level()] {
            assert_eq!(p.post_append_feedback(&c, 8, Some(&fb)), Decision::Keep, "{}", p.name());
        }
    }

    #[test]
    fn names_split_by_variant() {
        assert_eq!(SelfAttnGuided::default().name(), "self_attn");
        assert_eq!(SelfAttnGuided::token_level().name(), "self_attn_token");
        assert!(SelfAttnGuided::default().wants_feedback());
    }
}
