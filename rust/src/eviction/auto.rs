//! The autotuner's decision table: a PURE function from (prompt shape,
//! arena-pressure band, prefix-hit depth) to a concrete registry policy.
//!
//! It lives in `eviction/` next to the registry it picks from; the
//! scheduler side — pressure snapshots, per-request resolution through the
//! PR 5 override machinery, pick counters — lives in
//! `scheduler::autotune`. Purity is the determinism keystone: the same
//! (request, pressure snapshot) inputs yield the same choice at any worker
//! count, and the sim backend's token streams are policy-invariant
//! besides, so `--policy auto` digests stay bit-identical at workers
//! 1 vs 4 (the schedule-smoke CI leg compares them).

/// Request-level sentinel (`--policy auto`): not a registry entry — the
/// scheduler resolves it to one at submit time.
pub const AUTO_POLICY: &str = "auto";

/// Prompt-length threshold splitting chat tails from long-context
/// documents. The workload generator's chat prompts stay well under it,
/// its long-context prompts well over (see `workload::scenario`).
pub const LONG_CONTEXT_TOKENS: usize = 256;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptClass {
    /// Short conversational turn: the cache fits, recency dominates.
    Chat,
    /// Long document: retention quality decides answer quality.
    LongContext,
}

/// Classify a prompt by length alone — everything else the tuner uses
/// (prefix hits, pressure) arrives as separate inputs so the function
/// stays trivially pure.
pub fn classify_prompt(prompt_len: usize) -> PromptClass {
    if prompt_len >= LONG_CONTEXT_TOKENS {
        PromptClass::LongContext
    } else {
        PromptClass::Chat
    }
}

/// Arena pressure at submit time, banded by the PR 9 lock-free watermark
/// reads (see `scheduler::autotune::PressureSnapshot`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PressureBand {
    /// Below the low watermark: memory to spare.
    Low,
    /// Between the watermarks.
    Normal,
    /// Above the high watermark: preemption territory.
    High,
}

/// The decision table. `prefix_hit_blocks` is how many leading prompt
/// blocks the prefix cache would serve by reference: a request riding
/// shared pages must never get a `kills_tokens` policy, whose hole-punch
/// writes would force copy-on-write of every shared page at the worst
/// possible moment (pinned by `picks_never_kill_tokens_on_prefix_hits`).
pub fn pick_policy(
    class: PromptClass,
    band: PressureBand,
    prefix_hit_blocks: usize,
) -> &'static str {
    use PressureBand::*;
    use PromptClass::*;
    match (class, band) {
        // Short chat turns fit comfortably: the paper's structured
        // eviction is the all-round default.
        (Chat, Low) | (Chat, Normal) => "paged",
        // A fresh chat prompt under arena pressure degrades to the sliding
        // window (cheapest resident footprint) — unless it rides shared
        // prefix pages (see above).
        (Chat, High) => {
            if prefix_hit_blocks > 0 {
                "paged"
            } else {
                "streaming"
            }
        }
        // Roomy arena + long document: the gate drops only pages the
        // context has stopped attending to.
        (LongContext, Low) => "attention_gate",
        // Long context under pressure: rank pages by accumulated attention
        // mass and keep the heavy hitters.
        (LongContext, Normal) | (LongContext, High) => "self_attn",
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry;
    use super::*;

    const CLASSES: [PromptClass; 2] = [PromptClass::Chat, PromptClass::LongContext];
    const BANDS: [PressureBand; 3] =
        [PressureBand::Low, PressureBand::Normal, PressureBand::High];

    #[test]
    fn every_pick_is_a_registry_entry() {
        for class in CLASSES {
            for band in BANDS {
                for hits in [0usize, 1, 7] {
                    let name = pick_policy(class, band, hits);
                    assert!(
                        registry::lookup(name).is_some(),
                        "{class:?}/{band:?}/hits={hits} -> {name:?} not in registry"
                    );
                    assert_ne!(name, AUTO_POLICY, "the sentinel must never pick itself");
                }
            }
        }
    }

    #[test]
    fn picks_never_kill_tokens_on_prefix_hits() {
        for class in CLASSES {
            for band in BANDS {
                let name = pick_policy(class, band, 3);
                let info = registry::lookup(name).unwrap();
                assert!(
                    !info.kills_tokens,
                    "{class:?}/{band:?} with prefix hits picked {name} (kills_tokens)"
                );
            }
        }
    }

    #[test]
    fn classification_boundary() {
        assert_eq!(classify_prompt(0), PromptClass::Chat);
        assert_eq!(classify_prompt(LONG_CONTEXT_TOKENS - 1), PromptClass::Chat);
        assert_eq!(classify_prompt(LONG_CONTEXT_TOKENS), PromptClass::LongContext);
        assert_eq!(classify_prompt(4096), PromptClass::LongContext);
    }

    #[test]
    fn pressure_shapes_the_pick() {
        // chat sheds to the sliding window only when fresh AND pressured
        assert_eq!(pick_policy(PromptClass::Chat, PressureBand::High, 0), "streaming");
        assert_eq!(pick_policy(PromptClass::Chat, PressureBand::High, 2), "paged");
        assert_eq!(pick_policy(PromptClass::Chat, PressureBand::Low, 0), "paged");
        // long context trades the gate for mass ranking under pressure
        assert_eq!(
            pick_policy(PromptClass::LongContext, PressureBand::Low, 0),
            "attention_gate"
        );
        assert_eq!(pick_policy(PromptClass::LongContext, PressureBand::High, 0), "self_attn");
    }
}
