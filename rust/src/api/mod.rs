//! Session-based serving API — the crate's public entry point for
//! request lifecycle.
//!
//! The benchmark-shaped surface (`Request::new(id, ..)` with
//! caller-assigned ids + a blocking drain of finished outputs) is still
//! available on [`crate::scheduler::Scheduler`] for tests and benches,
//! but real serving goes through here:
//!
//!   * [`RequestBuilder`] — prompt, `max_new_tokens`, stop-token set,
//!     per-request eviction policy + KV budget override,
//!     [`Priority`], optional deadline in scheduler steps;
//!   * [`Session::submit`] — stamps a server-assigned [`RequestId`]
//!     (raced submissions can never collide) and returns a
//!     [`RequestHandle`];
//!   * [`RequestHandle`] — streams [`SeqEvent`]s
//!     (`Prefilled{ttft}` → `Token{tok, step}`* → `Finished(output)`,
//!     with `Preempted`/`Resumed` interleaved under memory pressure)
//!     and supports synchronous [`RequestHandle::cancel`]: arena blocks
//!     freed mid-decode, parked swap snapshots dropped, shared prefix
//!     pages unpinned by refcount, queue entries purged.
//!
//! Greedy outputs are bit-identical between the event stream and the
//! legacy `take_finished` drain — the concatenated `Token` events ARE
//! `Finished(out).tokens` — pinned in `tests/api_session.rs`, including
//! under forced preemption.

pub mod session;
pub mod types;

pub use session::{HandleState, RequestHandle, Session};
pub use types::{RequestBuilder, RequestId, SeqEvent};

// The scheduling class lives with the core request type; re-exported
// here so `api` is a self-sufficient import surface.
pub use crate::scheduler::request::Priority;
