//! The session: an engine handle that owns a [`Scheduler`] and exposes
//! the request lifecycle as per-request event streams.
//!
//! A [`Session`] is a cheaply cloneable handle (`Arc<Mutex<..>>`); the
//! lock is taken per scheduling round and per handle poll, never inside
//! the decode hot path. One thread drives [`Session::step`] (the engine
//! loop); any holder of a [`RequestHandle`] — same thread or another —
//! can poll events or cancel. Cancellation is SYNCHRONOUS: by the time
//! [`RequestHandle::cancel`] returns, the request's arena blocks are
//! released (shared prefix pages unpinned by refcount), any parked swap
//! snapshot is discarded, and its queue entry is purged.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::Result;

use super::types::{RequestBuilder, RequestId, SeqEvent};
use crate::eviction::validate_request_policy;
use crate::scheduler::{DecodeBackend, SchedConfig, Scheduler, StepReport};

/// Lifecycle of a request's event stream as seen by its handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandleState {
    /// Queued or running; more events may arrive.
    Active,
    /// Terminal `Finished` event emitted (it may still be queued in the
    /// handle, waiting to be polled).
    Finished,
    /// Cancelled; the stream ended without a `Finished` event.
    Cancelled,
}

struct Stream {
    events: VecDeque<SeqEvent>,
    state: HandleState,
}

struct Inner<B: DecodeBackend> {
    sched: Scheduler<B>,
    streams: HashMap<u64, Stream>,
    /// Monotonic server-assigned id counter (never reused).
    next_id: u64,
    /// Shutdown has begun: reject new submits, keep stepping the live
    /// requests until they drain (or the caller's deadline cancels them).
    draining: bool,
}

impl<B: DecodeBackend> Inner<B> {
    /// Shared cancel path: tear the request down in the scheduler and end
    /// its stream without a `Finished` event.
    fn cancel(&mut self, id: RequestId) -> bool {
        let ok = self.sched.cancel(id.raw());
        if ok {
            if let Some(s) = self.streams.get_mut(&id.raw()) {
                s.state = HandleState::Cancelled;
            }
        }
        ok
    }

    /// Move this round's scheduler events into the per-request streams.
    fn route_events(&mut self) {
        for (id, ev) in self.sched.take_events() {
            let Some(s) = self.streams.get_mut(&id) else {
                continue; // legacy direct-scheduler submission: no stream
            };
            match s.state {
                HandleState::Cancelled => {} // stream ended; drop the tail
                _ => {
                    if matches!(ev, SeqEvent::Finished(_)) {
                        s.state = HandleState::Finished;
                    }
                    s.events.push_back(ev);
                }
            }
        }
    }
}

/// Cloneable handle to one engine: submit, step, cancel.
pub struct Session<B: DecodeBackend> {
    inner: Arc<Mutex<Inner<B>>>,
}

impl<B: DecodeBackend> Clone for Session<B> {
    fn clone(&self) -> Self {
        Session { inner: Arc::clone(&self.inner) }
    }
}

impl<B: DecodeBackend> Session<B> {
    pub fn with_backend(backend: B, cfg: SchedConfig) -> Self {
        Self::from_scheduler(Scheduler::with_backend(backend, cfg))
    }

    /// Wrap an already-built scheduler (e.g. the PJRT-backed one). The
    /// session consumes the full event stream, so per-token streaming
    /// events are switched on here.
    pub fn from_scheduler(mut sched: Scheduler<B>) -> Self {
        sched.set_event_streaming(true);
        Session {
            inner: Arc::new(Mutex::new(Inner {
                sched,
                streams: HashMap::new(),
                next_id: 0,
                draining: false,
            })),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<B>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Submit a request: stamps a fresh server-assigned [`RequestId`]
    /// (ids are monotonic and never reused, so raced submissions cannot
    /// collide) and returns the handle streaming its events. Fails fast
    /// on an empty prompt or unknown eviction policy — nothing is queued
    /// on error.
    pub fn submit(&self, builder: RequestBuilder) -> Result<RequestHandle<B>> {
        anyhow::ensure!(builder.prompt_len() > 0, "empty prompt");
        let mut g = self.lock();
        anyhow::ensure!(!g.draining, "session shutting down; not accepting new requests");
        g.next_id += 1;
        let id = RequestId(g.next_id);
        let req = builder.build(id, &g.sched.cfg);
        // surface bad policy names at submit ("auto" is valid: the
        // scheduler resolves the sentinel when the request reaches it)
        validate_request_policy(&req.policy)?;
        g.streams.insert(
            id.raw(),
            Stream { events: VecDeque::new(), state: HandleState::Active },
        );
        g.sched.submit(req);
        // a submit-time rejection (e.g. zero budget) emits Finished now
        g.route_events();
        drop(g);
        Ok(RequestHandle { inner: Arc::clone(&self.inner), id })
    }

    /// One scheduling round; events are routed to their handles before
    /// this returns.
    pub fn step(&self) -> Result<StepReport> {
        let mut g = self.lock();
        let rep = g.sched.step()?;
        g.route_events();
        Ok(rep)
    }

    /// Step until nothing is queued or running.
    pub fn run_until_idle(&self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Cancel by id (see [`RequestHandle::cancel`]). `false` when the id
    /// is unknown or the request already finished — a clean no-op.
    pub fn cancel(&self, id: RequestId) -> bool {
        self.lock().cancel(id)
    }

    /// Drop the retained stream tail of a finished/cancelled request
    /// (long-lived servers call this once a stream has been delivered).
    pub fn forget(&self, id: RequestId) {
        self.lock().streams.remove(&id.raw());
    }

    pub fn is_idle(&self) -> bool {
        self.lock().sched.is_idle()
    }

    pub fn pending(&self) -> usize {
        self.lock().sched.pending()
    }

    pub fn running(&self) -> usize {
        self.lock().sched.running()
    }

    /// Escape hatch: run `f` against the locked scheduler (stats, arena
    /// accounting, legacy drains). Do not call other session methods from
    /// inside `f` — the session lock is held.
    pub fn with_scheduler<R>(&self, f: impl FnOnce(&mut Scheduler<B>) -> R) -> R {
        f(&mut self.lock().sched)
    }

    /// Stop accepting new submits (they fail fast with a clean error)
    /// while live requests keep running. Idempotent.
    pub fn begin_shutdown(&self) {
        self.lock().draining = true;
    }

    /// Has shutdown begun?
    pub fn is_draining(&self) -> bool {
        self.lock().draining
    }

    /// Graceful shutdown: reject new submits, then keep stepping until
    /// every live request drains or `deadline` elapses — at the deadline
    /// whatever is still live is cancelled (arena/swap reclaimed
    /// synchronously, streams end without `Finished`). Returns `true`
    /// when everything finished on its own, `false` when the deadline
    /// forced cancellations.
    pub fn shutdown(&self, deadline: std::time::Duration) -> Result<bool> {
        self.begin_shutdown();
        let end = std::time::Instant::now() + deadline;
        while !self.is_idle() {
            if std::time::Instant::now() >= end {
                let mut g = self.lock();
                for id in g.sched.live_ids() {
                    g.cancel(RequestId(id));
                }
                return Ok(false);
            }
            self.step()?;
        }
        Ok(true)
    }
}

impl Session<crate::runtime::SimBackend> {
    /// Session over the always-built deterministic sim backend.
    pub fn new_sim(cfg: SchedConfig) -> Self {
        Self::from_scheduler(Scheduler::new_sim(cfg))
    }
}

impl Session<crate::runtime::FaultyBackend<crate::runtime::SimBackend>> {
    /// Session over the sim backend wrapped in a deterministic fault
    /// injector (see [`crate::runtime::FaultPlan`]).
    pub fn new_sim_faulty(cfg: SchedConfig, plan: crate::runtime::FaultPlan) -> Self {
        Self::from_scheduler(Scheduler::new_sim_faulty(cfg, plan))
    }
}

/// Handle to one submitted request: poll its event stream, cancel it.
pub struct RequestHandle<B: DecodeBackend> {
    inner: Arc<Mutex<Inner<B>>>,
    id: RequestId,
}

impl<B: DecodeBackend> Clone for RequestHandle<B> {
    fn clone(&self) -> Self {
        RequestHandle { inner: Arc::clone(&self.inner), id: self.id }
    }
}

impl<B: DecodeBackend> RequestHandle<B> {
    fn lock(&self) -> MutexGuard<'_, Inner<B>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Pop the next queued event, if any (non-blocking; the engine thread
    /// must keep stepping the session for new events to appear).
    pub fn poll(&self) -> Option<SeqEvent> {
        self.lock().streams.get_mut(&self.id.raw())?.events.pop_front()
    }

    /// Drain every queued event.
    pub fn drain(&self) -> Vec<SeqEvent> {
        match self.lock().streams.get_mut(&self.id.raw()) {
            Some(s) => s.events.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Stream state; `Cancelled`/`Finished` are terminal. A forgotten
    /// stream reports `Cancelled` (its tail is gone either way).
    pub fn state(&self) -> HandleState {
        self.lock()
            .streams
            .get(&self.id.raw())
            .map_or(HandleState::Cancelled, |s| s.state)
    }

    /// Terminal and fully drained?
    pub fn is_done(&self) -> bool {
        let g = self.lock();
        match g.streams.get(&self.id.raw()) {
            Some(s) => s.state != HandleState::Active && s.events.is_empty(),
            None => true,
        }
    }

    /// Cancel this request NOW. On `true`, the scheduler has already —
    /// synchronously, before this returns — dropped the sequence's cache
    /// (every arena block released; shared prefix pages unpinned by
    /// refcount, so a page a live sharer holds survives), discarded any
    /// parked swap-pool snapshot, and purged the queue entry. No
    /// `Finished` event is emitted: cancellation is not completion.
    /// `false` when the request already finished (or was never known) —
    /// a clean no-op.
    pub fn cancel(&self) -> bool {
        self.lock().cancel(self.id)
    }
}
