//! Public request/response vocabulary of the session API: server-assigned
//! request identities, the per-sequence event stream, and the request
//! builder the engine stamps at submission.

use crate::scheduler::{Priority, Request, RequestOutput, SchedConfig};

/// Server-assigned identity of a submitted request. Callers never pick
/// ids (two raced submissions can therefore never collide); a
/// `RequestId` is only obtained from [`super::Session::submit`] and is
/// unique for the lifetime of its session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

impl RequestId {
    pub fn raw(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for RequestId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "req-{}", self.0)
    }
}

/// One lifecycle event of a submitted request, in stream order:
///
/// `Prefilled` (once, first admission) → `Token`* — interleaved with
/// `Preempted`/`Resumed` pairs under memory pressure — → `Finished`.
///
/// The concatenated `Token` payloads are exactly
/// `Finished(out).tokens`: a replayed token (recompute readmission) is
/// never re-emitted, so the stream is bit-identical to the one-shot
/// output. A cancelled request's stream simply ends — cancellation is
/// not completion, so no `Finished` is ever emitted for it.
#[derive(Debug, Clone, PartialEq)]
pub enum SeqEvent {
    /// Prompt processed and the request admitted for the first time;
    /// `ttft_s` is the time from submission to the first generated token
    /// (which exists as soon as prefill returns — vLLM semantics).
    Prefilled { ttft_s: f64 },
    /// One generated token; `step` is its 0-based index in the output.
    Token { tok: u32, step: usize },
    /// Evicted from the running set under memory pressure; `swap` is true
    /// when the victim was parked in the host swap pool (restore on
    /// readmission) rather than left to recompute-and-replay.
    Preempted { swap: bool },
    /// Readmitted after a preemption (either path). Token events resume
    /// where they stopped; replayed tokens are not re-emitted.
    Resumed,
    /// Terminal event: the completed output with serving metrics.
    Finished(RequestOutput),
}

impl SeqEvent {
    /// Short stable kind name (wire protocol + logs).
    pub fn kind(&self) -> &'static str {
        match self {
            SeqEvent::Prefilled { .. } => "prefilled",
            SeqEvent::Token { .. } => "token",
            SeqEvent::Preempted { .. } => "preempted",
            SeqEvent::Resumed => "resumed",
            SeqEvent::Finished(_) => "finished",
        }
    }
}

/// Builder for a submission. Everything except the prompt is optional:
/// policy and budget default to the SERVER's configured defaults
/// (`SchedConfig::default_policy` / `default_budget`) unless overridden
/// per request — the KeyDiff-style deployment story where different
/// requests tolerate different cache budgets. The id is NOT here: the
/// engine stamps a server-assigned [`RequestId`] at submission.
#[derive(Debug, Clone)]
pub struct RequestBuilder {
    prompt: Vec<u32>,
    max_new_tokens: usize,
    stop_tokens: Vec<u32>,
    policy: Option<String>,
    budget: Option<usize>,
    priority: Priority,
    deadline_steps: Option<u64>,
    stream_events: bool,
}

impl RequestBuilder {
    pub fn new(prompt: Vec<u32>) -> Self {
        RequestBuilder {
            prompt,
            max_new_tokens: 32,
            stop_tokens: Vec::new(),
            policy: None,
            budget: None,
            priority: Priority::Normal,
            deadline_steps: None,
            stream_events: true,
        }
    }

    /// Convenience: byte-tokenized text prompt.
    pub fn text(s: &str) -> Self {
        Self::new(crate::tokenizer::encode(s))
    }

    pub fn max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n.max(1);
        self
    }

    /// Add one stop token (generation stops when it is produced).
    pub fn stop_token(mut self, tok: u32) -> Self {
        self.stop_tokens.push(tok);
        self
    }

    /// Replace the whole stop-token set.
    pub fn stop_tokens(mut self, toks: Vec<u32>) -> Self {
        self.stop_tokens = toks;
        self
    }

    /// Per-request eviction policy override (see `eviction::make_policy`).
    pub fn policy(mut self, name: impl Into<String>) -> Self {
        self.policy = Some(name.into());
        self
    }

    /// Per-request KV cache budget override (tokens).
    pub fn budget(mut self, tokens: usize) -> Self {
        self.budget = Some(tokens);
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Deadline in scheduler steps after submission.
    pub fn deadline_steps(mut self, steps: u64) -> Self {
        self.deadline_steps = Some(steps);
        self
    }

    /// Emit per-token/lifecycle streaming events (default on). One-shot
    /// consumers that only read the terminal output turn this off so the
    /// engine never materializes events nobody reads.
    pub fn stream_events(mut self, on: bool) -> Self {
        self.stream_events = on;
        self
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    /// Stamp the server-assigned id and resolve the per-request overrides
    /// against the engine's configured defaults.
    pub(crate) fn build(self, id: RequestId, defaults: &SchedConfig) -> Request {
        Request {
            id: id.raw(),
            prompt: self.prompt,
            max_new_tokens: self.max_new_tokens,
            budget: self.budget.unwrap_or(defaults.default_budget),
            policy: self.policy.unwrap_or_else(|| defaults.default_policy.clone()),
            eos_token: None,
            stop_tokens: self.stop_tokens,
            priority: self.priority,
            deadline_steps: self.deadline_steps,
            stream_events: self.stream_events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_resolves_overrides_against_defaults() {
        let cfg = SchedConfig::default();
        let r = RequestBuilder::new(vec![1, 2, 3]).build(RequestId(9), &cfg);
        assert_eq!(r.id, 9);
        assert_eq!(r.policy, cfg.default_policy);
        assert_eq!(r.budget, cfg.default_budget);
        assert_eq!(r.priority, Priority::Normal);

        let r = RequestBuilder::new(vec![1])
            .policy("keydiff")
            .budget(64)
            .priority(Priority::High)
            .deadline_steps(40)
            .stop_token(5)
            .max_new_tokens(0)
            .build(RequestId(10), &cfg);
        assert_eq!(r.policy, "keydiff");
        assert_eq!(r.budget, 64);
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.deadline_steps, Some(40));
        assert_eq!(r.stop_tokens, vec![5]);
        assert_eq!(r.max_new_tokens, 1, "zero-length generations are clamped");
    }

    #[test]
    fn event_kinds_are_stable() {
        assert_eq!(SeqEvent::Resumed.kind(), "resumed");
        assert_eq!(SeqEvent::Token { tok: 1, step: 0 }.kind(), "token");
    }
}
