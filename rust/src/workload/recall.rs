//! Associative-recall prompts — the Rust mirror of
//! `python/compile/recall_task.py` (same token-space constants; the model
//! is trained on this format, so keep them in sync).
//!
//! A prompt is a stream of (key, value) pairs with the queried pair planted
//! at a controllable depth, ending in `[QUERY, key]`; a model that retained
//! the needle pair in its KV cache answers with the right value token.
//! Recall accuracy vs cache budget is our real-model stand-in for the
//! paper's LongBench QA scores (DESIGN.md §4).

use crate::util::rng::Pcg32;

pub const PAD: u32 = 0;
pub const KEY_BASE: u32 = 1;
pub const N_KEYS: u32 = 31;
pub const VAL_BASE: u32 = 32;
pub const N_VALS: u32 = 31;
pub const QUERY: u32 = 64;

#[derive(Debug, Clone)]
pub struct RecallPrompt {
    pub tokens: Vec<u32>,
    pub answer: u32,
    /// (key position, value position) of the needle pair in the prompt.
    pub needle: (usize, usize),
}

/// Build one eval prompt of exactly `prompt_len` tokens (even, >= 8) with
/// the needle planted at `needle_frac` of the pair stream.
pub fn make_prompt(rng: &mut Pcg32, prompt_len: usize, needle_frac: f64) -> RecallPrompt {
    assert!(prompt_len >= 8 && prompt_len % 2 == 0);
    // per-sequence random key -> value mapping
    let vmap: Vec<u32> = (0..N_KEYS).map(|_| VAL_BASE + rng.below(N_VALS)).collect();
    let qk = rng.below(N_KEYS);
    let n_pairs = (prompt_len - 2) / 2;
    let needle_at = ((n_pairs as f64 * needle_frac) as usize).min(n_pairs - 1);
    let mut tokens = Vec::with_capacity(prompt_len);
    for p in 0..n_pairs {
        let k = if p == needle_at {
            qk
        } else {
            // distractor: any key but the queried one
            let mut k = rng.below(N_KEYS - 1);
            if k >= qk {
                k += 1;
            }
            k
        };
        tokens.push(KEY_BASE + k);
        tokens.push(vmap[k as usize]);
    }
    tokens.push(QUERY);
    tokens.push(KEY_BASE + qk);
    RecallPrompt {
        tokens,
        answer: vmap[qk as usize],
        needle: (2 * needle_at, 2 * needle_at + 1),
    }
}

/// Multi-hop variant (HotpotQA-shaped): two needles must BOTH be retained —
/// key -> bridge value, bridge (reused as key) -> final value. The query
/// asks for the first key; a model with either hop evicted fails.
pub fn make_multihop_prompt(rng: &mut Pcg32, prompt_len: usize) -> RecallPrompt {
    // Approximation with the single-needle machinery: plant the needle
    // early (frac 0.1) where naive recency policies will have evicted it.
    make_prompt(rng, prompt_len, 0.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_shape() {
        let mut rng = Pcg32::new(1);
        let p = make_prompt(&mut rng, 64, 0.25);
        assert_eq!(p.tokens.len(), 64);
        assert_eq!(p.tokens[62], QUERY);
        let qk = p.tokens[63];
        assert!((KEY_BASE..KEY_BASE + N_KEYS).contains(&qk));
        assert!((VAL_BASE..VAL_BASE + N_VALS).contains(&p.answer));
        // needle key matches the query and its value is the answer
        assert_eq!(p.tokens[p.needle.0], qk);
        assert_eq!(p.tokens[p.needle.1], p.answer);
    }

    #[test]
    fn needle_is_unique() {
        let mut rng = Pcg32::new(2);
        for _ in 0..50 {
            let p = make_prompt(&mut rng, 96, 0.3);
            let qk = p.tokens[95];
            let occurrences = p.tokens[..94]
                .iter()
                .step_by(2)
                .filter(|&&t| t == qk)
                .count();
            assert_eq!(occurrences, 1, "needle key must appear exactly once");
        }
    }

    #[test]
    fn needle_frac_controls_depth() {
        let mut rng = Pcg32::new(3);
        let early = make_prompt(&mut rng, 128, 0.05);
        let late = make_prompt(&mut rng, 128, 0.9);
        assert!(early.needle.0 < late.needle.0);
    }

    #[test]
    fn tokens_in_model_vocab() {
        let mut rng = Pcg32::new(4);
        let p = make_prompt(&mut rng, 64, 0.5);
        assert!(p.tokens.iter().all(|&t| t < 256));
    }
}
