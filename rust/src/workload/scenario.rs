//! Multi-tenant SLO scenarios: named, fully-seeded traffic mixes that
//! the `paged-eviction slo` driver replays through [`crate::scheduler::MultiEngine`].
//!
//! A [`Scenario`] couples an arrival process ([`super::arrivals`]), a
//! request *shape*, and a per-tenant shared system-prompt prefix. The two
//! canonical shapes mirror the two regimes the paper's evaluation keeps
//! separate:
//!
//!   * [`RequestShape::Chat`] — short prompts, long decodes: a decode
//!     flood where TPOT and preemption behaviour dominate.
//!   * [`RequestShape::LongContext`] — LongBench-style long prompts with
//!     short decodes: prefill-heavy replays where TTFT, the prefix index
//!     and chunked prefill dominate.
//!
//! Every tenant gets its own shared prefix (same token recipe as the
//! `schedule` subcommand: block-aligned, drawn below 256) so the PR 4
//! prefix index sees realistic cross-request reuse *within* a tenant and
//! zero reuse *across* tenants. `synthesize(seed)` is a pure function:
//! same scenario + same seed → byte-identical request list, which is what
//! lets CI assert digest equality across `--workers` counts.

use crate::util::rng::Pcg32;

use super::arrivals::ArrivalProcess;
use super::recall::make_prompt;

/// Latency objectives a request must meet to count toward goodput.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token ceiling, milliseconds.
    pub ttft_ms: f64,
    /// Time-per-output-token ceiling, milliseconds.
    pub tpot_ms: f64,
}

/// The two canonical request shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestShape {
    /// Chat-style: short even prompts (32..=94 tokens past the shared
    /// prefix), long decodes (48..=96 new tokens).
    Chat,
    /// LongBench-style replay: long even prompts (256..=512 tokens past
    /// the prefix — at least 8 full 16-token blocks, so chunked prefill
    /// genuinely spans rounds), short decodes (8..=24 new tokens).
    LongContext,
    /// Arena-pressure mix: every 7th request (indices 0, 7, 14, …) is a
    /// "marathon" (64-token prompt, 256 new tokens — it keeps growing
    /// until it owns most of a small arena) and the rest are "sprints"
    /// (64-token prompt, 2..=4 new tokens) that arrive behind it. Sized
    /// so a deliberately undersized arena forces cross-worker preempts
    /// while the sprint backlog forces steals — the `saturate-steal`
    /// decontention scenario.
    SprintMarathon,
}

impl RequestShape {
    pub fn label(&self) -> &'static str {
        match self {
            RequestShape::Chat => "chat",
            RequestShape::LongContext => "long-context",
            RequestShape::SprintMarathon => "sprint-marathon",
        }
    }
}

/// A named, replayable traffic scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: &'static str,
    /// Distinct tenants, each with its own shared system-prompt prefix.
    pub tenants: usize,
    /// Total requests across all tenants.
    pub requests: usize,
    pub arrivals: ArrivalProcess,
    pub shape: RequestShape,
    /// Shared prefix length per tenant, in tokens (even, block-aligned
    /// at 16-token pages for real prefix-index hits).
    pub shared_prefix_len: usize,
    pub slo: SloSpec,
    /// Scheduler `prefill_chunk` this scenario runs with (0 = one-shot).
    pub prefill_chunk: usize,
}

/// One synthesized request of a scenario trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthRequest {
    /// Arrival time in seconds from trace start.
    pub at_s: f64,
    pub tenant: usize,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

impl Scenario {
    /// Names of the built-in scenarios, in canonical order.
    pub fn builtin_names() -> &'static [&'static str] {
        &["bursty-chat", "longbench-replay", "diurnal-mixed", "saturate-steal"]
    }

    /// Look up a built-in scenario by name.
    pub fn builtin(name: &str) -> Option<Scenario> {
        match name {
            // Multi-tenant chat flood under on/off bursts: mean load is
            // modest but ON-phase spikes force preemption and stealing.
            "bursty-chat" => Some(Scenario {
                name: "bursty-chat",
                tenants: 6,
                requests: 48,
                arrivals: ArrivalProcess::Bursty {
                    rate_on: 120.0,
                    rate_off: 8.0,
                    mean_on: 0.15,
                    mean_off: 0.20,
                },
                shape: RequestShape::Chat,
                shared_prefix_len: 64,
                slo: SloSpec { ttft_ms: 2_000.0, tpot_ms: 150.0 },
                prefill_chunk: 0,
            }),
            // LongBench-style long-prompt replay: few tenants, big
            // prompts, chunked prefill on so one giant prompt cannot
            // head-of-line block a decode round.
            "longbench-replay" => Some(Scenario {
                name: "longbench-replay",
                tenants: 2,
                requests: 12,
                arrivals: ArrivalProcess::Poisson { rate: 30.0 },
                shape: RequestShape::LongContext,
                shared_prefix_len: 32,
                slo: SloSpec { ttft_ms: 4_000.0, tpot_ms: 250.0 },
                prefill_chunk: 64,
            }),
            // Slow sinusoidal ramp mixing many chat tenants — the gentle
            // scenario for local profiling, not wired into CI smoke.
            "diurnal-mixed" => Some(Scenario {
                name: "diurnal-mixed",
                tenants: 4,
                requests: 32,
                arrivals: ArrivalProcess::Diurnal { base: 5.0, peak: 60.0, period: 2.0 },
                shape: RequestShape::Chat,
                shared_prefix_len: 32,
                slo: SloSpec { ttft_ms: 2_500.0, tpot_ms: 150.0 },
                prefill_chunk: 0,
            }),
            // Everything arrives at once into an arena sized well below
            // the marathons' combined footprint: at `--page-size 8
            // --arena-blocks 56` each marathon grows to ~40 blocks, so
            // 4 of them force ArenaDry → cross-worker preemption, while
            // the sprint backlog keeps idle workers stealing. At
            // `--workers 1` the marathons simply run back to back (one
            // fits alone), so the steal/cross-preempt floors in
            // `bench_gate.py` apply only to multi-worker rows. The SLO
            // ceilings are deliberately huge: this scenario measures
            // contention-counter plumbing, not latency.
            "saturate-steal" => Some(Scenario {
                name: "saturate-steal",
                tenants: 4,
                requests: 28,
                arrivals: ArrivalProcess::Poisson { rate: 120.0 },
                shape: RequestShape::SprintMarathon,
                shared_prefix_len: 0,
                slo: SloSpec { ttft_ms: 120_000.0, tpot_ms: 1_000.0 },
                prefill_chunk: 0,
            }),
            _ => None,
        }
    }

    /// Synthesize the full request trace: arrival times from the
    /// configured process, per-tenant shared prefixes, and shaped
    /// prompt/decode lengths. Pure in `(self, seed)`.
    pub fn synthesize(&self, seed: u64) -> Vec<SynthRequest> {
        assert!(self.tenants > 0 && self.requests > 0);
        assert!(self.shared_prefix_len % 2 == 0, "prefix must stay even for make_prompt");
        let mut rng = Pcg32::new(seed);
        let times = self.arrivals.times(&mut rng, self.requests);
        // one shared system-prompt prefix per tenant — same token recipe
        // as cmd_schedule so the prefix index hashes full blocks
        let prefixes: Vec<Vec<u32>> = (0..self.tenants)
            .map(|_| (0..self.shared_prefix_len).map(|_| rng.below(200)).collect())
            .collect();
        times
            .into_iter()
            .enumerate()
            .map(|(i, at_s)| {
                let tenant = rng.usize_below(self.tenants);
                let (tail_len, gen) = match self.shape {
                    // 32..=94 even tail, 48..=96 decode
                    RequestShape::Chat => {
                        (32 + 2 * rng.below(32) as usize, 48 + rng.below(49) as usize)
                    }
                    // 256..=512 even tail, 8..=24 decode
                    RequestShape::LongContext => {
                        (256 + 2 * rng.below(129) as usize, 8 + rng.below(17) as usize)
                    }
                    // fixed 64-token prompts; every 7th request decodes
                    // 256 tokens (marathon), the rest 2..=4 (sprint)
                    RequestShape::SprintMarathon => {
                        (64, if i % 7 == 0 { 256 } else { 2 + rng.below(3) as usize })
                    }
                };
                let mut prompt = prefixes[tenant].clone();
                prompt.extend_from_slice(&make_prompt(&mut rng, tail_len, 0.4).tokens);
                SynthRequest { at_s, tenant, prompt, max_new_tokens: gen }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_lookup_round_trips() {
        for name in Scenario::builtin_names() {
            let s = Scenario::builtin(name).expect("builtin must resolve");
            assert_eq!(&s.name, name);
        }
        assert!(Scenario::builtin("no-such-scenario").is_none());
    }

    #[test]
    fn synthesize_is_deterministic_per_seed() {
        for name in Scenario::builtin_names() {
            let s = Scenario::builtin(name).unwrap();
            let a = s.synthesize(42);
            let b = s.synthesize(42);
            assert_eq!(a, b, "{name}: same seed must synthesize identically");
            let c = s.synthesize(43);
            assert_ne!(a, c, "{name}: a different seed must change the trace");
            assert_eq!(a.len(), s.requests);
        }
    }

    #[test]
    fn tenants_share_prefixes_and_shapes_hold() {
        let s = Scenario::builtin("bursty-chat").unwrap();
        let reqs = s.synthesize(7);
        // every request of a tenant starts with that tenant's prefix
        for t in 0..s.tenants {
            let mine: Vec<&SynthRequest> = reqs.iter().filter(|r| r.tenant == t).collect();
            if mine.len() < 2 {
                continue;
            }
            let prefix = &mine[0].prompt[..s.shared_prefix_len];
            for r in &mine[1..] {
                assert_eq!(&r.prompt[..s.shared_prefix_len], prefix);
            }
        }
        for r in &reqs {
            let tail = r.prompt.len() - s.shared_prefix_len;
            assert!((32..=94).contains(&tail), "chat tail {tail}");
            assert!((48..=96).contains(&r.max_new_tokens));
        }

        let long = Scenario::builtin("longbench-replay").unwrap();
        for r in long.synthesize(7) {
            // at least 8 full 16-token blocks even before the prefix
            assert!(r.prompt.len() - long.shared_prefix_len >= 256);
            assert!((8..=24).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn saturate_steal_mixes_marathons_and_sprints() {
        let s = Scenario::builtin("saturate-steal").unwrap();
        let reqs = s.synthesize(7);
        assert_eq!(reqs.len(), 28);
        let mut marathons = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.prompt.len(), 64, "fixed prompts keep the capacity math exact");
            if i % 7 == 0 {
                assert_eq!(r.max_new_tokens, 256, "req {i} must be a marathon");
                marathons += 1;
            } else {
                assert!((2..=4).contains(&r.max_new_tokens), "req {i} must be a sprint");
            }
        }
        // 4 marathons × (64+256)/8 = 160 blocks at page 8 — far past the
        // 56-block arena the CI leg runs with, so pressure is guaranteed
        assert_eq!(marathons, 4);
    }
}
