//! Arrival traces for the serving benches: Poisson arrivals (open loop),
//! all-at-once bursts (closed loop, the paper's 64-concurrent setup), and
//! text trace FILES with per-request overrides (`policy=` / `budget=` /
//! `priority=` / `deadline=`) for the `schedule --trace` driver.

use anyhow::{Context, Result};

use crate::scheduler::Priority;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Poisson arrival rate (requests/sec); None = all arrive at t=0
    /// (the paper's batch setup).
    pub arrival_rate: Option<f64>,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    /// (arrival time in seconds, prompt seed) per request.
    pub arrivals: Vec<(f64, u64)>,
}

impl ArrivalTrace {
    pub fn generate(cfg: &TraceConfig) -> ArrivalTrace {
        let mut rng = Pcg32::new(cfg.seed);
        let mut t = 0.0;
        let arrivals = (0..cfg.n_requests)
            .map(|_| {
                if let Some(rate) = cfg.arrival_rate {
                    t += rng.exp(rate);
                }
                (t, rng.next_u64())
            })
            .collect();
        ArrivalTrace { arrivals }
    }
}

/// One request spec from a trace file. Every field is optional — unset
/// fields fall back to the driver's CLI defaults — so a trace can be as
/// terse as `at=0` or carry full per-request overrides.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceEntry {
    /// Scheduler step at which to submit (default 0 = before the first
    /// round). Entries need not be sorted.
    pub at_step: u64,
    pub prompt_len: Option<usize>,
    pub gen: Option<usize>,
    /// Per-request eviction policy override.
    pub policy: Option<String>,
    /// Per-request KV budget override (tokens).
    pub budget: Option<usize>,
    pub priority: Option<Priority>,
    /// Deadline in scheduler steps after submission.
    pub deadline_steps: Option<u64>,
    /// Per-request prompt RNG seed (default: the driver's rolling rng).
    pub seed: Option<u64>,
}

/// Parse a trace file: one request per non-empty line, `#` comments,
/// whitespace-separated `key=value` fields:
///
/// ```text
/// # key=value ...: at, prompt_len, gen, policy, budget, priority,
/// #                deadline, seed
/// at=0 prompt_len=96 gen=48
/// at=2 prompt_len=64 gen=32 policy=keydiff budget=64 priority=high deadline=200
/// ```
pub fn parse_trace(text: &str) -> Result<Vec<TraceEntry>> {
    let mut entries = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut e = TraceEntry::default();
        for field in line.split_whitespace() {
            let (key, val) = field.split_once('=').with_context(|| {
                format!(
                    "line {}: field {field:?} is missing '=' (expected key=value)",
                    lineno + 1
                )
            })?;
            let ctx = || format!("line {}: key {key:?}: bad value {val:?}", lineno + 1);
            match key {
                "at" => e.at_step = val.parse().with_context(ctx)?,
                "prompt_len" => e.prompt_len = Some(val.parse().with_context(ctx)?),
                "gen" => e.gen = Some(val.parse().with_context(ctx)?),
                "policy" => {
                    // fail at parse time, not mid-run at submit: the
                    // registry owns the valid set ("auto" included)
                    crate::eviction::validate_request_policy(val).with_context(ctx)?;
                    e.policy = Some(val.to_string());
                }
                "budget" => e.budget = Some(val.parse().with_context(ctx)?),
                "priority" => e.priority = Some(Priority::parse(val).with_context(ctx)?),
                "deadline" => e.deadline_steps = Some(val.parse().with_context(ctx)?),
                "seed" => e.seed = Some(val.parse().with_context(ctx)?),
                other => anyhow::bail!(
                    "line {}: unknown trace key {other:?} (expected one of: at, \
                     prompt_len, gen, policy, budget, priority, deadline, seed)",
                    lineno + 1
                ),
            }
        }
        entries.push(e);
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_trace_all_at_zero() {
        let tr = ArrivalTrace::generate(&TraceConfig {
            n_requests: 10,
            prompt_len: 64,
            max_new_tokens: 32,
            arrival_rate: None,
            seed: 1,
        });
        assert_eq!(tr.arrivals.len(), 10);
        assert!(tr.arrivals.iter().all(|&(t, _)| t == 0.0));
    }

    #[test]
    fn poisson_trace_monotone_and_rate() {
        let tr = ArrivalTrace::generate(&TraceConfig {
            n_requests: 2000,
            prompt_len: 64,
            max_new_tokens: 32,
            arrival_rate: Some(50.0),
            seed: 2,
        });
        let times: Vec<f64> = tr.arrivals.iter().map(|&(t, _)| t).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        let total = times.last().unwrap();
        let rate = 2000.0 / total;
        assert!((rate - 50.0).abs() < 5.0, "empirical rate {rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig {
            n_requests: 5,
            prompt_len: 64,
            max_new_tokens: 8,
            arrival_rate: Some(10.0),
            seed: 3,
        };
        let a = ArrivalTrace::generate(&cfg);
        let b = ArrivalTrace::generate(&cfg);
        assert_eq!(a.arrivals, b.arrivals);
    }

    #[test]
    fn trace_file_parses_overrides_comments_and_defaults() {
        let text = "\n\
            # a comment line\n\
            at=0 prompt_len=96 gen=48\n\
            at=2 policy=keydiff budget=64 priority=high deadline=200 # tail comment\n\
            seed=9\n";
        let es = parse_trace(text).unwrap();
        assert_eq!(es.len(), 3);
        assert_eq!(es[0].prompt_len, Some(96));
        assert_eq!(es[0].gen, Some(48));
        assert_eq!(es[0].policy, None, "unset fields stay CLI-defaulted");
        assert_eq!(es[1].at_step, 2);
        assert_eq!(es[1].policy.as_deref(), Some("keydiff"));
        assert_eq!(es[1].budget, Some(64));
        assert_eq!(es[1].priority, Some(Priority::High));
        assert_eq!(es[1].deadline_steps, Some(200));
        assert_eq!(es[2].at_step, 0);
        assert_eq!(es[2].seed, Some(9));
    }

    /// Render the full context chain — parse errors wrap a cause, and
    /// the line/field context lives in the outer layers.
    fn err_text(text: &str) -> String {
        format!("{:#}", parse_trace(text).expect_err("input must be rejected"))
    }

    #[test]
    fn bare_token_error_names_line_and_field() {
        let msg = err_text("at=0 nonsense");
        assert!(msg.contains("line 1"), "missing line number: {msg}");
        assert!(msg.contains("\"nonsense\""), "missing field: {msg}");
        assert!(msg.contains("key=value"), "missing expectation: {msg}");
    }

    #[test]
    fn unknown_key_error_names_line_and_key() {
        let msg = err_text("frobnicate=3");
        assert!(msg.contains("line 1"), "missing line number: {msg}");
        assert!(msg.contains("\"frobnicate\""), "missing key: {msg}");
        assert!(msg.contains("expected one of"), "missing key list: {msg}");
    }

    #[test]
    fn policy_names_validate_at_parse_time() {
        let msg = err_text("at=0 policy=lru");
        assert!(msg.contains("line 1"), "missing line number: {msg}");
        assert!(msg.contains("\"lru\""), "missing value: {msg}");
        assert!(msg.contains("valid:"), "missing the registry's set: {msg}");
        // aliases and the autotuner sentinel are all valid trace values
        for ok in ["auto", "self_attn", "attn_gate", "paged_eviction"] {
            let es = parse_trace(&format!("at=0 policy={ok}")).unwrap();
            assert_eq!(es[0].policy.as_deref(), Some(ok));
        }
    }

    #[test]
    fn non_numeric_value_error_names_line_key_and_value() {
        let msg = err_text("budget=lots");
        assert!(msg.contains("line 1"), "missing line number: {msg}");
        assert!(msg.contains("\"budget\""), "missing key: {msg}");
        assert!(msg.contains("\"lots\""), "missing value: {msg}");
    }

    #[test]
    fn bad_priority_error_names_line_key_and_value() {
        let msg = err_text("priority=urgent");
        assert!(msg.contains("line 1"), "missing line number: {msg}");
        assert!(msg.contains("\"priority\""), "missing key: {msg}");
        assert!(msg.contains("\"urgent\""), "missing value: {msg}");
    }

    #[test]
    fn errors_report_the_offending_line_not_the_first() {
        // line 1 is fine, line 2 is a comment, line 3 is broken
        let msg = err_text("at=0 gen=8\n# fine\nat=2 budget=oops");
        assert!(msg.contains("line 3"), "wrong line attribution: {msg}");
        assert!(!msg.contains("line 1"), "blamed the wrong line: {msg}");
    }

    #[test]
    fn empty_input_is_empty_not_an_error() {
        assert!(parse_trace("").unwrap().is_empty());
        assert!(parse_trace("# only comments\n\n").unwrap().is_empty());
    }
}
