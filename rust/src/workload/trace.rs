//! Arrival traces for the serving benches: Poisson arrivals (open loop) or
//! all-at-once bursts (closed loop, the paper's 64-concurrent setup).

use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Poisson arrival rate (requests/sec); None = all arrive at t=0
    /// (the paper's batch setup).
    pub arrival_rate: Option<f64>,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    /// (arrival time in seconds, prompt seed) per request.
    pub arrivals: Vec<(f64, u64)>,
}

impl ArrivalTrace {
    pub fn generate(cfg: &TraceConfig) -> ArrivalTrace {
        let mut rng = Pcg32::new(cfg.seed);
        let mut t = 0.0;
        let arrivals = (0..cfg.n_requests)
            .map(|_| {
                if let Some(rate) = cfg.arrival_rate {
                    t += rng.exp(rate);
                }
                (t, rng.next_u64())
            })
            .collect();
        ArrivalTrace { arrivals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_trace_all_at_zero() {
        let tr = ArrivalTrace::generate(&TraceConfig {
            n_requests: 10,
            prompt_len: 64,
            max_new_tokens: 32,
            arrival_rate: None,
            seed: 1,
        });
        assert_eq!(tr.arrivals.len(), 10);
        assert!(tr.arrivals.iter().all(|&(t, _)| t == 0.0));
    }

    #[test]
    fn poisson_trace_monotone_and_rate() {
        let tr = ArrivalTrace::generate(&TraceConfig {
            n_requests: 2000,
            prompt_len: 64,
            max_new_tokens: 32,
            arrival_rate: Some(50.0),
            seed: 2,
        });
        let times: Vec<f64> = tr.arrivals.iter().map(|&(t, _)| t).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        let total = times.last().unwrap();
        let rate = 2000.0 / total;
        assert!((rate - 50.0).abs() < 5.0, "empirical rate {rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig {
            n_requests: 5,
            prompt_len: 64,
            max_new_tokens: 8,
            arrival_rate: Some(10.0),
            seed: 3,
        };
        let a = ArrivalTrace::generate(&cfg);
        let b = ArrivalTrace::generate(&cfg);
        assert_eq!(a.arrivals, b.arrivals);
    }
}
