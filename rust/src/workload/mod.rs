//! Workload generation: the associative-recall task the tiny model is
//! trained on (real-model accuracy track), synthetic LongBench-shaped
//! episodes (simulator accuracy track), Poisson arrival traces for the
//! serving benches, and the SLO traffic engine — seeded arrival processes
//! ([`arrivals`]) plus multi-tenant scenario synthesis ([`scenario`]) for
//! the `paged-eviction slo` driver and the `slo-smoke` CI gate.

pub mod arrivals;
pub mod recall;
pub mod scenario;
pub mod trace;

pub use arrivals::ArrivalProcess;
pub use recall::RecallPrompt;
pub use scenario::{RequestShape, Scenario, SloSpec, SynthRequest};
pub use trace::{ArrivalTrace, TraceConfig};
