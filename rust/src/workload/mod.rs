//! Workload generation: the associative-recall task the tiny model is
//! trained on (real-model accuracy track), synthetic LongBench-shaped
//! episodes (simulator accuracy track), and Poisson arrival traces for the
//! serving benches.

pub mod recall;
pub mod trace;

pub use recall::RecallPrompt;
pub use trace::{ArrivalTrace, TraceConfig};
