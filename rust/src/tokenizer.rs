//! Byte-level tokenizer: the vocabulary is exactly the 256 byte values, so
//! any UTF-8 text round-trips losslessly and token ids never leave the
//! model's vocab. (The recall workload instead speaks raw token ids — see
//! `workload::recall`.)

/// Encode text as byte tokens.
pub fn encode(text: &str) -> Vec<u32> {
    text.as_bytes().iter().map(|&b| b as u32).collect()
}

/// Decode byte tokens back to text (lossy on invalid UTF-8).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xff) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "hello, paged eviction!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo — 😀";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn tokens_stay_in_vocab() {
        assert!(encode("😀€ñ").iter().all(|&t| t < 256));
    }
}
