//! Minimal JSON parser + serializer (RFC 8259 subset sufficient for the
//! artifact manifest and the serving wire protocol).
//!
//! Supports all JSON value kinds, nested arbitrarily; numbers are f64;
//! strings support the standard escapes incl. \uXXXX (BMP + surrogate
//! pairs). No trailing commas, no comments — strict like serde_json.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the key — for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key: {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]`; errors on non-integers.
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected usize")))
            .collect()
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&(*n as i64).to_string());
                } else {
                    out.push_str(&n.to_string());
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"nested":{"s":"x\ny"},"z":-3}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[1,2.5]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn req_errors_name_key() {
        let v = Json::parse("{}").unwrap();
        let e = v.req("missing_key").unwrap_err().to_string();
        assert!(e.contains("missing_key"));
    }
}
