//! Minimal TOML-subset parser for serving config files (toml/serde are not
//! in the offline vendor set).
//!
//! Supported: `[section]` headers, `key = value` with string/int/float/
//! bool/inline-array values, `#` comments, blank lines. This covers the
//! whole `ServeConfig` surface; nested tables and multi-line values are
//! intentionally out of scope.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_list(&self) -> Option<Vec<usize>> {
        match self {
            TomlValue::Array(xs) => xs.iter().map(|x| x.as_usize()).collect(),
            _ => None,
        }
    }
}

/// `section.key -> value`; keys before any `[section]` land under `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let value = parse_value(val.trim())
            .with_context(|| format!("line {}: bad value {:?}", lineno + 1, val.trim()))?;
        doc.entry(section.clone())
            .or_default()
            .insert(key.trim().to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: '#' inside quoted strings is not supported
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(body) = s.strip_prefix('"') {
        let Some(inner) = body.strip_suffix('"') else {
            bail!("unterminated string")
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let Some(inner) = body.strip_suffix(']') else {
            bail!("unterminated array")
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let elems = inner
            .split(',')
            .map(|e| parse_value(e.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(elems));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unrecognized value")
}

/// Typed accessor with section.key error messages.
pub fn get<'d>(doc: &'d TomlDoc, section: &str, key: &str) -> Option<&'d TomlValue> {
    doc.get(section).and_then(|s| s.get(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[server]
model = "sim-1b"
port = 7071
max_concurrency = 8     # sequences

[cache]
page_size = 16
budget = 1024
policy = "paged"
buckets = [128, 256, 512]
grow = true
load_factor = 0.75
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(get(&d, "server", "model").unwrap().as_str(), Some("sim-1b"));
        assert_eq!(get(&d, "server", "port").unwrap().as_usize(), Some(7071));
        assert_eq!(get(&d, "cache", "grow").unwrap().as_bool(), Some(true));
        assert_eq!(
            get(&d, "cache", "buckets").unwrap().as_usize_list(),
            Some(vec![128, 256, 512])
        );
        assert_eq!(get(&d, "cache", "load_factor").unwrap().as_f64(), Some(0.75));
    }

    #[test]
    fn comments_stripped_not_in_strings() {
        let d = parse("x = \"a # b\" # trailing").unwrap();
        assert_eq!(get(&d, "", "x").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just a line").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = what").is_err());
    }

    #[test]
    fn empty_and_blank_ok() {
        assert!(parse("").unwrap().is_empty());
        let d = parse("\n\n# only comments\n").unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn negative_and_float_values() {
        let d = parse("a = -3\nb = 2.5\nc = [1, 2.0]").unwrap();
        assert_eq!(get(&d, "", "a").unwrap(), &TomlValue::Int(-3));
        assert_eq!(get(&d, "", "b").unwrap().as_f64(), Some(2.5));
        assert!(get(&d, "", "c").unwrap().as_usize_list().is_none());
    }
}
