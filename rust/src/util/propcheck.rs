//! Mini property-based testing harness (proptest is not in the offline
//! vendor set). Runs a property against N seeded random cases and, on
//! failure, re-runs with the failing seed reported so the case can be
//! reproduced by pinning `PropConfig::only_seed`.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Re-run exactly one case (from a failure report).
    pub only_seed: Option<u64>,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0x9e3779b97f4a7c15, only_seed: None }
    }
}

/// Run `prop(rng)` for `cfg.cases` independent seeds; panic with the failing
/// case seed on the first failure (property returns Err(description)).
pub fn check<F>(name: &str, cfg: &PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let seeds: Vec<u64> = match cfg.only_seed {
        Some(s) => vec![s],
        None => (0..cfg.cases as u64).map(|i| cfg.seed.wrapping_add(i)).collect(),
    };
    for case_seed in seeds {
        let mut rng = Pcg32::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (reproduce with only_seed={case_seed}): {msg}"
            );
        }
    }
}

/// Convenience: default config.
pub fn quick<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    check(name, &PropConfig::default(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quick("add-commutes", |rng| {
            let a = rng.below(1000) as u64;
            let b = rng.below(1000) as u64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "only_seed=")]
    fn failing_property_reports_seed() {
        quick("always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn only_seed_runs_single_case() {
        let mut runs = 0;
        check(
            "count",
            &PropConfig { only_seed: Some(42), ..Default::default() },
            |_| {
                runs += 1;
                Ok(())
            },
        );
        assert_eq!(runs, 1);
    }
}
