//! Minimal CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! auto-generated `--help`. Declarative enough for the main binary's
//! subcommands and all example/bench drivers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative arg parser: declare options, then `parse` an argv tail.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    program: String,
    about: String,
    specs: Vec<Spec>,
}

#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &str, about: &str) -> Self {
        ArgSpec { program: program.into(), about: about.into(), specs: vec![] }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n\nOptions:", self.program, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else if let Some(d) = &spec.default {
                format!(" <value>  (default: {d})")
            } else {
                " <value>  (required)".to_string()
            };
            let _ = writeln!(s, "  --{}{}\n        {}", spec.name, tail, spec.help);
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} is a flag, takes no value"));
                    }
                    flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if !spec.is_flag && !values.contains_key(&spec.name) {
                match &spec.default {
                    Some(d) => {
                        values.insert(spec.name.clone(), d.clone());
                    }
                    None => {
                        return Err(format!(
                            "missing required --{}\n\n{}",
                            spec.name,
                            self.usage()
                        ))
                    }
                }
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse the process argv (skipping argv[0] and an optional subcommand);
    /// print usage and exit on error.
    pub fn parse_or_exit(&self, skip: usize) -> Args {
        let argv: Vec<String> = std::env::args().skip(skip).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    /// Comma-separated list.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }

    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get_list(name)
            .iter()
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}")))
            .collect()
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("budget", "1024", "cache budget")
            .req("model", "model name")
            .flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = spec().parse(&sv(&["--model", "sim-1b", "--verbose"])).unwrap();
        assert_eq!(a.get("model"), "sim-1b");
        assert_eq!(a.get_usize("budget"), 1024);
        assert!(a.has("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = spec().parse(&sv(&["--model=x", "--budget=256"])).unwrap();
        assert_eq!(a.get("model"), "x");
        assert_eq!(a.get_usize("budget"), 256);
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&sv(&["--budget", "2"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&sv(&["--model", "m", "--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse(&sv(&["pos1", "--model", "m", "pos2"])).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn list_parsing() {
        let s = ArgSpec::new("t", "").opt("budgets", "64,128,256", "");
        let a = s.parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize_list("budgets"), vec![64, 128, 256]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(e.contains("--budget"));
        assert!(e.contains("cache budget"));
    }
}
