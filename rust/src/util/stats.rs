//! Serving statistics: online summaries, percentile sketches, throughput
//! windows. Replaces hdrhistogram/criterion's stat layer for our benches.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact-percentile latency recorder. Stores every sample (fine at our
/// request volumes); `pctl` uses the nearest-rank method.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { samples: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Nearest-rank percentile, q in [0, 100].
    pub fn pctl(&mut self, q: f64) -> f64 {
        assert!(!self.samples.is_empty(), "empty histogram");
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn report(&mut self, unit: &str) -> String {
        if self.is_empty() {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p90={:.3}{u} p99={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.pctl(50.0),
            self.pctl(90.0),
            self.pctl(99.0),
            self.pctl(100.0),
            u = unit,
        )
    }
}

/// Tokens/sec over a measured wall-clock span.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub tokens: u64,
    pub seconds: f64,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.seconds <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.seconds
        }
    }
}

/// Fixed-width text table writer for paper-style bench output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.add(i as f64);
        }
        assert_eq!(h.pctl(50.0), 50.0);
        assert_eq!(h.pctl(90.0), 90.0);
        assert_eq!(h.pctl(99.0), 99.0);
        assert_eq!(h.pctl(100.0), 100.0);
        assert_eq!(h.pctl(1.0), 1.0);
    }

    #[test]
    fn percentile_single_sample() {
        let mut h = Histogram::new();
        h.add(3.25);
        assert_eq!(h.pctl(50.0), 3.25);
        assert_eq!(h.pctl(99.0), 3.25);
    }

    #[test]
    fn throughput() {
        let t = Throughput { tokens: 500, seconds: 2.0 };
        assert_eq!(t.per_sec(), 250.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["budget", "tok/s"]);
        t.row(vec!["256".into(), "3020.1".into()]);
        t.row(vec!["4096".into(), "99.5".into()]);
        let r = t.render();
        assert!(r.contains("budget"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
