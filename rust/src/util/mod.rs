//! Zero-dependency utility substrates.
//!
//! The offline vendor set ships only `xla`, `anyhow` and `log`, so every
//! other building block a serving framework normally pulls from crates.io
//! (JSON, CLI parsing, RNG, statistics, property testing) is implemented
//! here from scratch and unit-tested in place.

pub mod args;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod toml;
