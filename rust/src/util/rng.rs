//! Deterministic PRNG (PCG32 / xoshiro-free, no external crates).
//!
//! Used by workload generators, the accuracy simulator and the property
//! test harness. Seeded explicitly everywhere so every benchmark row in
//! EXPERIMENTS.md is reproducible bit-for-bit.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n.wrapping_neg() % n {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson arrivals.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-like heavy-tailed sample in [0, n) with exponent s — models the
    /// "few critical tokens" attention concentration the paper builds on.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the continuous approximation; fine for simulation.
        let u = self.f64();
        let x = ((1.0 - u) as f64).powf(-1.0 / (s - 1.0));
        ((x - 1.0) as usize).min(n - 1)
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Pcg32::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let mut r = Pcg32::new(6);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 2.0)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[0] > 2_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
