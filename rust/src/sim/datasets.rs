//! LongBench-shaped dataset profiles for the simulator.
//!
//! Each profile controls (a) the importance structure of prompt tokens and
//! (b) how retained-importance translates into the reported score, chosen
//! to match the task type the paper evaluates (§5.1):
//!
//!   GovReport / MultiNews — long-document summarization, scored by ROUGE:
//!     importance is broad (coverage matters), score degrades smoothly with
//!     lost mass.
//!   HotpotQA — multi-hop QA: a few needle tokens carry the answer; score
//!     is (mostly) all-or-nothing per needle.
//!   MultiFieldQA / Qasper — single-doc QA: needles plus supporting
//!     context.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScoreKind {
    /// score = full_score * coverage^gamma  (summarization / ROUGE)
    Coverage { gamma: f64 },
    /// score = base + (full - base) * P(all needles retained)
    Needle { n_needles: usize, base: f64 },
}

#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Zipf exponent of the importance distribution (higher = more
    /// concentrated attention).
    pub zipf_s: f64,
    /// Fraction of importance mass pinned on the first few tokens
    /// (attention sinks).
    pub sink_mass: f64,
    /// Recency boost half-life in tokens (0 = none).
    pub recency_halflife: f64,
    /// Full-cache score on the paper's scale (ROUGE or QA F1).
    pub full_score: f64,
    pub score: ScoreKind,
    /// Prompt length used in the Fig. 2 sweep.
    pub prompt_len: usize,
    /// Decode length.
    pub gen_len: usize,
}

/// The five LongBench datasets of the paper's Figure 2.
pub const DATASETS: [DatasetProfile; 5] = [
    DatasetProfile {
        name: "govreport",
        zipf_s: 1.3,
        sink_mass: 0.08,
        recency_halflife: 512.0,
        full_score: 30.0, // paper: full-cache GovReport ROUGE ~30 (1B)
        score: ScoreKind::Coverage { gamma: 0.55 },
        prompt_len: 6144,
        gen_len: 512,
    },
    DatasetProfile {
        name: "multinews",
        zipf_s: 1.35,
        sink_mass: 0.08,
        recency_halflife: 384.0,
        full_score: 24.5, // paper: full-cache MultiNews ROUGE ~24.5 (3B)
        score: ScoreKind::Coverage { gamma: 0.5 },
        prompt_len: 5120,
        gen_len: 384,
    },
    DatasetProfile {
        name: "hotpotqa",
        zipf_s: 1.8,
        sink_mass: 0.05,
        recency_halflife: 256.0,
        full_score: 52.0,
        score: ScoreKind::Needle { n_needles: 2, base: 12.0 },
        prompt_len: 8192,
        gen_len: 64,
    },
    DatasetProfile {
        name: "multifieldqa",
        zipf_s: 1.7,
        sink_mass: 0.05,
        recency_halflife: 256.0,
        full_score: 46.0,
        score: ScoreKind::Needle { n_needles: 1, base: 14.0 },
        prompt_len: 4096,
        gen_len: 64,
    },
    DatasetProfile {
        name: "qasper",
        zipf_s: 1.6,
        sink_mass: 0.06,
        recency_halflife: 320.0,
        full_score: 40.0,
        score: ScoreKind::Needle { n_needles: 1, base: 10.0 },
        prompt_len: 4096,
        gen_len: 96,
    },
];

pub fn dataset(name: &str) -> Option<&'static DatasetProfile> {
    DATASETS.iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert!(dataset("govreport").is_some());
        assert!(dataset("nope").is_none());
    }

    #[test]
    fn profiles_sane() {
        for d in &DATASETS {
            assert!(d.zipf_s > 1.0, "{}", d.name);
            assert!(d.full_score > 0.0);
            assert!(d.prompt_len >= 1024);
            assert!((0.0..0.5).contains(&d.sink_mass));
        }
    }
}
