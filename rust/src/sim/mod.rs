//! Accuracy simulator track (DESIGN.md §4): reproduces the *shape* of the
//! paper's Figure 2 / Figure 4(d-i) accuracy-vs-budget curves at the
//! paper's own scales (budgets 256-4096) without the unavailable Llama
//! checkpoints and LongBench data.
//!
//! The simulator drives the EXACT production cache + policy code
//! (`kvcache::SeqCache`, `eviction::*`); only the token stream and the
//! score channels are synthetic. It encodes the paper's empirical premises
//! explicitly (documented, tunable):
//!
//!  * attention importance is heavy-tailed with sinks + recency
//!    (StreamingLLM/H2O observations);
//!  * the attention-free channels are noisy proxies of importance, with
//!    proxy fidelity ordered V/K-ratio > inverse-key-norm > keydiff
//!    (Devoto et al.'s key-norm correlation + the paper's Fig. 2 outcome);
//!
//! and then *measures the consequence* of block-wise vs token-wise vs
//! recency eviction under those premises — which granularity retains more
//! of what matters, where fragmentation bites, where crossovers fall.
//! The H2O oracle (true importance, attention-based) provides the upper
//! bound the paper excludes for deployability reasons.

pub mod attention_sim;
pub mod datasets;
pub mod h2o;
pub mod rouge;

pub use attention_sim::{
    positional_mass, simulate_episode, simulate_episodes, simulate_mean,
    simulate_mean_serial, simulate_mean_threads, EpisodeResult, SimConfig,
};
pub use datasets::{DatasetProfile, ScoreKind, DATASETS};
pub use h2o::H2oOracle;
