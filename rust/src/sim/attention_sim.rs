//! The attention-mass episode simulator.
//!
//! Generates a prompt whose tokens carry latent *importance* (their share
//! of future attention mass), derives the three attention-free score
//! channels from it with channel-specific proxy noise, then replays the
//! production cache/eviction machinery and scores what survived.

use crate::eviction::{AttnFeedback, Decision, EvictionPolicy, PrefillScores};
use crate::kvcache::SeqCache;
use crate::util::rng::Pcg32;

use super::datasets::{DatasetProfile, ScoreKind};

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub budget: usize,
    pub page_size: usize,
    pub seed: u64,
    /// Proxy fidelity per channel as a CORRELATION in [0,1] between the
    /// proxy and the true (standardized log) attention mass:
    /// proxy = corr * z(ln w) + sqrt(1-corr^2) * noise.
    /// Defaults encode the paper's observed proxy-quality ordering
    /// (V/K ratio > inverse key norm > keydiff); the ablation bench
    /// sweeps them. 1.0 = oracle (H2O-style attention-score access).
    pub proxy_corr: [f64; 3],
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            budget: 1024,
            page_size: 16,
            seed: 0,
            proxy_corr: [0.72, 0.45, 0.30],
        }
    }
}

#[derive(Debug, Clone)]
pub struct EpisodeResult {
    /// retained attention mass averaged over decode steps, in [0, 1]
    pub coverage: f64,
    /// fraction of needle tokens live at the end (1.0 when no needles)
    pub needles_retained: f64,
    /// dataset-scale score (ROUGE / F1 points)
    pub score: f64,
    pub partial_blocks: usize,
    pub table_updates: u64,
    pub mask_updates: u64,
}

/// Latent importance for each prompt position.
fn importance_profile(
    d: &DatasetProfile,
    rng: &mut Pcg32,
    needles: &[usize],
) -> Vec<f64> {
    let n = d.prompt_len;
    let mut w = vec![0f64; n];
    // heavy-tailed base mass: random permutation of zipf ranks
    let mut ranks: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut ranks);
    for i in 0..n {
        w[i] = 1.0 / ((ranks[i] + 1) as f64).powf(d.zipf_s);
    }
    // attention sinks: first tokens get a fixed share of total mass
    let total: f64 = w.iter().sum();
    let sink_n = 4;
    for i in 0..sink_n.min(n) {
        w[i] += d.sink_mass * total / sink_n as f64;
    }
    // recency boost applied to the tail
    if d.recency_halflife > 0.0 {
        for i in 0..n {
            let age = (n - 1 - i) as f64;
            // recent tokens draw disproportionate attention (StreamingLLM's
            // premise); 3x boost at age 0 decaying with the half-life
            w[i] *= 1.0 + 3.0 * (-age / d.recency_halflife).exp();
        }
    }
    // needles dominate their neighbourhood (QA answer spans)
    let mean = w.iter().sum::<f64>() / n as f64;
    for &p in needles {
        w[p] = w[p].max(mean * 50.0);
    }
    w
}

/// Derive the three proxy channels from importance with channel-specific
/// correlation. Channel semantics match the live system: 0 = V/K ratio
/// (higher = keep), 1 = key L2 (lower = keep), 2 = keydiff cos (lower =
/// keep). The proxy is corr * z + sqrt(1-corr^2) * eps over the
/// standardized log-importance z, so `corr` IS the proxy-truth Pearson
/// correlation regardless of the importance distribution's scale.
fn proxy_channels(w: &[f64], corr: &[f64; 3], rng: &mut Pcg32) -> [Vec<f32>; 3] {
    let n = w.len();
    let logs: Vec<f64> = w.iter().map(|&wi| wi.max(1e-12).ln()).collect();
    let mean = logs.iter().sum::<f64>() / n as f64;
    let var = logs.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / n as f64;
    let std = var.sqrt().max(1e-9);
    let mut chans = [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)];
    for &l in &logs {
        let z = (l - mean) / std;
        for (c, ch) in chans.iter_mut().enumerate() {
            let a = corr[c].clamp(0.0, 1.0);
            let p = a * z + (1.0 - a * a).sqrt() * rng.normal();
            // channels 1 and 2 are "lower = keep" in the live system
            ch.push(if c == 0 { p as f32 } else { -p as f32 });
        }
    }
    chans
}

/// The backend-side attention-mass model: a PURE function of (position,
/// horizon) that [`crate::runtime::SimBackend`] samples to serve its
/// per-step feedback channel. It mirrors the episode model's shape —
/// attention sinks at the head, a recency boost at the tail, deterministic
/// position-hashed jitter in between — scaled by residence time so the
/// value reads as ACCUMULATED mass, which is what [`AttnFeedback`]
/// carries. Purity is the determinism keystone for `--policy auto`: the
/// feedback a sequence sees depends only on its own positions, never on
/// scheduling order or worker count.
pub fn positional_mass(pos: u32, horizon: u32) -> f32 {
    // splitmix64 of the position -> per-token jitter in [0.5, 1.5)
    let mut x = (u64::from(pos) << 1) ^ 0x9e37_79b9_7f4a_7c15;
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    let jitter = 0.5 + (x >> 40) as f32 / (1u64 << 24) as f32;
    let age = horizon.saturating_sub(pos).max(1) as f32;
    // sinks draw a fixed multiple of ambient attention for their whole
    // residence; recent tokens spike and decay with a 32-step half-life
    let sink = if pos < 4 { 8.0 } else { 1.0 };
    let recency = 1.0 + 3.0 * (-(age - 1.0) / 32.0).exp();
    jitter * sink * recency * age
}

/// Run one episode of `policy` on dataset `d` and score the outcome.
pub fn simulate_episode(
    d: &DatasetProfile,
    policy: &dyn EvictionPolicy,
    cfg: &SimConfig,
) -> EpisodeResult {
    let mut rng = Pcg32::with_stream(cfg.seed, 0x5eed + d.prompt_len as u64);
    let bs = cfg.page_size;

    // --- plant needles (QA datasets) ---
    let needles: Vec<usize> = match d.score {
        ScoreKind::Needle { n_needles, .. } => (0..n_needles)
            .map(|_| {
                // needles live in the middle 60% of the prompt — past the
                // sinks, before the recency window
                let lo = d.prompt_len / 5;
                let hi = 4 * d.prompt_len / 5;
                lo + rng.usize_below(hi - lo)
            })
            .collect(),
        _ => vec![],
    };

    let w = importance_profile(d, &mut rng, &needles);
    let channels = proxy_channels(&w, &cfg.proxy_corr, &mut rng);
    let scores = PrefillScores { channels, len: d.prompt_len };

    // --- prefill eviction (token-level, pre-pagination) ---
    let keep = policy.prefill_keep(&scores, cfg.budget);
    let capacity = (cfg.budget / bs + 4).max(keep.len() / bs + 4);
    let mut cache = SeqCache::new(bs, capacity);
    let entries: Vec<(u32, [f32; 3])> = keep
        .iter()
        .map(|&i| {
            (i as u32, [
                scores.channels[0][i],
                scores.channels[1][i],
                scores.channels[2][i],
            ])
        })
        .collect();
    cache.load_prefill(&entries, d.prompt_len as u32);

    // --- decode loop: new tokens draw modest importance (generated text
    // attends mostly to the prompt). Their true mass comes from the SAME
    // lognormal model as the prompt (z = -0.5, i.e. below-average tokens),
    // so proxies and truth stay consistent across prompt and generation. ---
    let logs: Vec<f64> = w.iter().map(|x| x.max(1e-12).ln()).collect();
    let ln_mean = logs.iter().sum::<f64>() / logs.len() as f64;
    let ln_var =
        logs.iter().map(|l| (l - ln_mean).powi(2)).sum::<f64>() / logs.len() as f64;
    let ln_std = ln_var.sqrt().max(1e-9);
    let gen_z = -0.5f64;
    let gen_wi = (ln_mean + gen_z * ln_std).exp();
    let mut total_mass: f64 = w.iter().sum();
    let mut live_mass: f64 = keep.iter().map(|&i| w[i]).sum();
    // positions -> importance for retention accounting
    let mut imp = w.clone();
    // Feedback-consuming policies receive the TRUE accumulated mass — the
    // same latent importance the episode is scored against — through the
    // attention-feedback channel, the sim's analogue of a backend that
    // measures real attention weights. Everything else still sees only the
    // noisy proxy channels, so fig2 exposes exactly the truth-vs-proxy gap.
    let wants_fb = policy.wants_feedback();
    let mut fb = AttnFeedback {
        mass: if wants_fb { w.iter().map(|&x| x as f32).collect() } else { Vec::new() },
    };
    let mut coverage_acc = 0.0f64;
    for step in 0..d.gen_len {
        // retained share BEFORE this step's append (decision quality view)
        coverage_acc += live_mass / total_mass;
        if !cache.ensure_block() {
            cache.grow(cache.capacity_blocks() + 4);
            assert!(cache.ensure_block());
        }
        let wi = gen_wi;
        let _ = step;
        // decode-time tokens score via the same correlation model at the
        // same z as their true mass
        let z = gen_z;
        let sc = [
            (cfg.proxy_corr[0] * z + (1.0 - cfg.proxy_corr[0].powi(2)).sqrt() * rng.normal()) as f32,
            (-(cfg.proxy_corr[1] * z + (1.0 - cfg.proxy_corr[1].powi(2)).sqrt() * rng.normal())) as f32,
            (-(cfg.proxy_corr[2] * z + (1.0 - cfg.proxy_corr[2].powi(2)).sqrt() * rng.normal())) as f32,
        ];
        imp.push(wi);
        if wants_fb {
            fb.mass.push(wi as f32);
        }
        total_mass += wi;
        live_mass += wi;
        cache.append(sc);
        let decision = if wants_fb {
            policy.post_append_feedback(&cache, cfg.budget, Some(&fb))
        } else {
            policy.post_append(&cache, cfg.budget)
        };
        match decision {
            Decision::Keep => {}
            Decision::EvictBlock(i) => {
                let mut lost = 0.0;
                for (_, pos, _) in cache.blocks()[i].live_tokens() {
                    lost += imp[pos as usize];
                }
                #[cfg(test)]
                if std::env::var("SIM_DEBUG").is_ok() {
                    let blk = &cache.blocks()[i];
                    eprintln!(
                        "step {step}: evict logical {i} mean_ch0 {:.3} first_pos {} lost_mass_share {:.4}",
                        blk.mean_score(0),
                        blk.positions[0],
                        lost / total_mass
                    );
                }
                live_mass -= lost;
                cache.evict_block(i);
            }
            Decision::KillTokens(ts) => {
                for (bi, off) in ts {
                    let pos = cache.blocks()[bi].positions[off];
                    live_mass -= imp[pos as usize];
                    cache.kill_token(bi, off);
                }
            }
        }
    }
    let coverage = coverage_acc / d.gen_len.max(1) as f64;
    #[cfg(test)]
    {
        let recomputed: f64 = cache
            .live_token_list()
            .iter()
            .map(|&(_, _, pos, _)| imp[pos as usize])
            .sum();
        if (recomputed - live_mass).abs() > 1e-6 * total_mass {
            eprintln!(
                "LIVE MASS DRIFT: tracked {live_mass:.4} recomputed {recomputed:.4} (total {total_mass:.4})"
            );
        }
        eprintln!(
            "end: live {} tokens, final share {:.3}, avg coverage {:.3}, evicted_blocks {}",
            cache.live_tokens(),
            recomputed / total_mass,
            coverage,
            cache.stats.blocks_evicted
        );
    }

    let live_positions: std::collections::HashSet<u32> = cache
        .live_token_list()
        .iter()
        .map(|&(_, _, p, _)| p)
        .collect();
    let needles_retained = if needles.is_empty() {
        1.0
    } else {
        needles
            .iter()
            .filter(|&&p| live_positions.contains(&(p as u32)))
            .count() as f64
            / needles.len() as f64
    };

    let score = match d.score {
        ScoreKind::Coverage { gamma } => d.full_score * coverage.powf(gamma),
        ScoreKind::Needle { base, .. } => {
            // all-or-nothing per needle, plus partial credit via coverage
            base + (d.full_score - base)
                * needles_retained
                * coverage.powf(0.15)
        }
    };

    EpisodeResult {
        coverage,
        needles_retained,
        score,
        partial_blocks: cache.partial_blocks(),
        table_updates: cache.stats.table_updates,
        mask_updates: cache.stats.mask_updates,
    }
}

/// Run `n` episodes (seed `i` = `cfg.seed + i * 7919`, matching the
/// historical serial derivation) and return their results in episode
/// order. Episodes are seed-deterministic and fully independent, so they
/// are fanned out across up to `threads` OS threads with
/// `std::thread::scope`; every episode's RNG depends only on its own seed,
/// so the returned vector is bit-identical for any thread count.
pub fn simulate_episodes(
    d: &DatasetProfile,
    policy: &dyn EvictionPolicy,
    cfg: &SimConfig,
    n: usize,
    threads: usize,
) -> Vec<EpisodeResult> {
    let run_one = |i: usize| {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64 * 7919);
        simulate_episode(d, policy, &c)
    };
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(run_one).collect();
    }
    let chunk = (n + threads - 1) / threads;
    let mut out: Vec<Option<EpisodeResult>> = vec![None; n];
    std::thread::scope(|s| {
        for (t, slots) in out.chunks_mut(chunk).enumerate() {
            let run_one = &run_one;
            s.spawn(move || {
                for (j, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(run_one(t * chunk + j));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("episode worker died")).collect()
}

/// Average `n` episodes (different seeds) across all available cores.
/// Bit-identical to the serial path: episodes are computed independently
/// and accumulated in episode order on the calling thread.
pub fn simulate_mean(
    d: &DatasetProfile,
    policy: &dyn EvictionPolicy,
    cfg: &SimConfig,
    n: usize,
) -> EpisodeResult {
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    simulate_mean_threads(d, policy, cfg, n, threads)
}

/// Serial reference for [`simulate_mean`] (used by the determinism tests).
pub fn simulate_mean_serial(
    d: &DatasetProfile,
    policy: &dyn EvictionPolicy,
    cfg: &SimConfig,
    n: usize,
) -> EpisodeResult {
    simulate_mean_threads(d, policy, cfg, n, 1)
}

/// [`simulate_mean`] with an explicit thread count.
pub fn simulate_mean_threads(
    d: &DatasetProfile,
    policy: &dyn EvictionPolicy,
    cfg: &SimConfig,
    n: usize,
    threads: usize,
) -> EpisodeResult {
    let results = simulate_episodes(d, policy, cfg, n, threads);
    let mut acc = EpisodeResult {
        coverage: 0.0,
        needles_retained: 0.0,
        score: 0.0,
        partial_blocks: 0,
        table_updates: 0,
        mask_updates: 0,
    };
    for r in &results {
        acc.coverage += r.coverage;
        acc.needles_retained += r.needles_retained;
        acc.score += r.score;
        acc.partial_blocks += r.partial_blocks;
        acc.table_updates += r.table_updates;
        acc.mask_updates += r.mask_updates;
    }
    acc.coverage /= n as f64;
    acc.needles_retained /= n as f64;
    acc.score /= n as f64;
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::make_policy;
    use crate::sim::datasets::dataset;

    fn run(ds: &str, pol: &str, budget: usize) -> EpisodeResult {
        let d = dataset(ds).unwrap();
        let p = make_policy(pol).unwrap();
        simulate_mean(d, p.as_ref(), &SimConfig { budget, ..Default::default() }, 8)
    }

    #[test]
    fn full_cache_is_upper_bound() {
        for ds in ["govreport", "hotpotqa"] {
            let full = run(ds, "full", 1024);
            assert!(full.coverage > 0.999, "{ds}: {}", full.coverage);
            for pol in ["paged", "streaming", "inverse_key_norm", "keydiff"] {
                let r = run(ds, pol, 1024);
                assert!(
                    r.score <= full.score + 1e-6,
                    "{ds}/{pol}: {} > full {}",
                    r.score,
                    full.score
                );
            }
        }
    }

    #[test]
    fn score_monotone_in_budget() {
        for pol in ["paged", "streaming"] {
            let lo = run("govreport", pol, 256);
            let hi = run("govreport", pol, 4096);
            assert!(
                hi.score > lo.score,
                "{pol}: budget 4096 ({}) should beat 256 ({})",
                hi.score,
                lo.score
            );
        }
    }

    #[test]
    fn paged_beats_recency_on_needles() {
        // Needles are planted mid-prompt: pure recency (StreamingLLM) loses
        // them at tight budgets; importance-driven paged keeps them.
        let paged = run("hotpotqa", "paged", 512);
        let stream = run("hotpotqa", "streaming", 512);
        assert!(
            paged.needles_retained > stream.needles_retained,
            "paged {} vs streaming {}",
            paged.needles_retained,
            stream.needles_retained
        );
    }

    #[test]
    fn unstructured_fragments_structured_does_not() {
        let paged = run("govreport", "paged", 1024);
        let ikn = run("govreport", "inverse_key_norm", 1024);
        assert_eq!(paged.partial_blocks, 0);
        assert!(ikn.partial_blocks > 0);
        // paged touches metadata once per page; unstructured once per token
        assert!(ikn.mask_updates > 4 * paged.table_updates);
        assert_eq!(paged.mask_updates, 0);
    }

    #[test]
    fn parallel_mean_is_bit_identical_to_serial() {
        let d = dataset("qasper").unwrap();
        for pol in ["paged", "streaming", "inverse_key_norm"] {
            let p = make_policy(pol).unwrap();
            let cfg = SimConfig { budget: 512, ..Default::default() };
            let a = simulate_mean_threads(d, p.as_ref(), &cfg, 6, 1);
            let b = simulate_mean_threads(d, p.as_ref(), &cfg, 6, 4);
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "{pol}: score drifted");
            assert_eq!(a.coverage.to_bits(), b.coverage.to_bits(), "{pol}");
            assert_eq!(a.needles_retained.to_bits(), b.needles_retained.to_bits(), "{pol}");
            assert_eq!(
                (a.partial_blocks, a.table_updates, a.mask_updates),
                (b.partial_blocks, b.table_updates, b.mask_updates),
                "{pol}"
            );
            let c = simulate_mean_serial(d, p.as_ref(), &cfg, 6);
            assert_eq!(a.score.to_bits(), c.score.to_bits(), "{pol}: serial alias");
        }
    }

    #[test]
    fn episode_order_is_thread_count_invariant() {
        let d = dataset("multifieldqa").unwrap();
        let p = make_policy("paged").unwrap();
        let cfg = SimConfig { budget: 256, ..Default::default() };
        let serial = simulate_episodes(d, p.as_ref(), &cfg, 5, 1);
        for threads in [2usize, 3, 8] {
            let par = simulate_episodes(d, p.as_ref(), &cfg, 5, threads);
            assert_eq!(par.len(), serial.len());
            for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                assert_eq!(a.score.to_bits(), b.score.to_bits(), "episode {i} @ {threads}t");
            }
        }
    }

    #[test]
    fn truth_feedback_beats_the_proxy_it_degrades_to() {
        // SelfAttnGuided ranks pages by TRUE accumulated mass via the
        // feedback channel; paged ranks the same pages through the
        // 0.72-correlation proxy with an identical trigger and prefill.
        // Equal budget, truth must retain at least as much attention mass
        // — the fig2 acceptance criterion's unit-test backstop.
        for ds in ["govreport", "hotpotqa"] {
            let truth = run(ds, "self_attn", 512);
            let proxy = run(ds, "paged", 512);
            assert!(
                truth.coverage >= proxy.coverage - 1e-9,
                "{ds}: self_attn coverage {} < paged {}",
                truth.coverage,
                proxy.coverage
            );
        }
    }

    #[test]
    fn feedback_policies_stay_deterministic_and_parallel_safe() {
        let d = dataset("qasper").unwrap();
        for pol in ["self_attn", "self_attn_token", "attention_gate"] {
            let p = make_policy(pol).unwrap();
            let cfg = SimConfig { budget: 512, ..Default::default() };
            let a = simulate_mean_threads(d, p.as_ref(), &cfg, 6, 1);
            let b = simulate_mean_threads(d, p.as_ref(), &cfg, 6, 4);
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "{pol}: score drifted");
            assert_eq!(a.coverage.to_bits(), b.coverage.to_bits(), "{pol}");
        }
    }

    #[test]
    fn positional_mass_model_shape() {
        // pure: same inputs, same bits
        assert_eq!(positional_mass(7, 100).to_bits(), positional_mass(7, 100).to_bits());
        // strictly positive over any live range
        for pos in 0..64 {
            assert!(positional_mass(pos, 64) > 0.0, "pos {pos}");
        }
        // sinks dominate ambient tokens of comparable age (worst-case
        // jitter ratio still leaves >2x headroom)
        assert!(positional_mass(0, 100) > 2.0 * positional_mass(10, 100));
        // accumulation: the same token has collected more mass after a
        // longer residence (jitter and sink factors cancel)
        assert!(positional_mass(20, 100) > positional_mass(20, 30));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = dataset("qasper").unwrap();
        let p = make_policy("paged").unwrap();
        let cfg = SimConfig { budget: 512, ..Default::default() };
        let a = simulate_episode(d, p.as_ref(), &cfg);
        let b = simulate_episode(d, p.as_ref(), &cfg);
        assert_eq!(a.score, b.score);
    }
}

#[cfg(test)]
mod probe_tests {
    use super::*;
    use crate::eviction::make_policy;
    use crate::sim::datasets::dataset;

    #[test]
    fn probe_episode_live_mass_consistency() {
        let d = dataset("govreport").unwrap();
        let p = make_policy("paged").unwrap();
        let cfg = SimConfig { budget: 4096, ..Default::default() };
        // re-run the episode body with recomputation at the end
        let r = simulate_episode(d, p.as_ref(), &cfg);
        println!("episode coverage {:.3}", r.coverage);
    }

    #[test]
    fn probe_prefill_coverage() {
        let d = dataset("govreport").unwrap();
        let mut rng = Pcg32::with_stream(0, 0x5eed + d.prompt_len as u64);
        let w = importance_profile(d, &mut rng, &[]);
        let channels = proxy_channels(&w, &[0.72, 0.45, 0.30], &mut rng);
        let total: f64 = w.iter().sum();
        for budget in [256usize, 1024, 4096] {
            for pol in ["paged", "inverse_key_norm"] {
                let p = make_policy(pol).unwrap();
                let scores = PrefillScores { channels: channels.clone(), len: d.prompt_len };
                let keep = p.prefill_keep(&scores, budget);
                let mass: f64 = keep.iter().map(|&i| w[i]).sum();
                println!("b={budget} {pol}: keep {} cov {:.3}", keep.len(), mass / total);
            }
        }
    }
}
