//! ROUGE-1 / ROUGE-2 / ROUGE-L (F1) from scratch — the paper's
//! summarization metric, used by the real-model track to score generated
//! continuations against the full-cache reference generation.

use std::collections::HashMap;

fn ngram_counts<'a>(tokens: &'a [&'a str], n: usize) -> HashMap<Vec<&'a str>, usize> {
    let mut m = HashMap::new();
    if tokens.len() < n {
        return m;
    }
    for w in tokens.windows(n) {
        *m.entry(w.to_vec()).or_insert(0) += 1;
    }
    m
}

fn f1(overlap: usize, cand: usize, refr: usize) -> f64 {
    if cand == 0 || refr == 0 || overlap == 0 {
        return 0.0;
    }
    let p = overlap as f64 / cand as f64;
    let r = overlap as f64 / refr as f64;
    2.0 * p * r / (p + r)
}

/// ROUGE-N F1 between whitespace-tokenized candidate and reference.
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> f64 {
    let c: Vec<&str> = candidate.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    let cc = ngram_counts(&c, n);
    let rc = ngram_counts(&r, n);
    let overlap: usize = cc
        .iter()
        .map(|(g, &cnt)| cnt.min(rc.get(g).copied().unwrap_or(0)))
        .sum();
    let c_total = c.len().saturating_sub(n - 1);
    let r_total = r.len().saturating_sub(n - 1);
    f1(overlap, c_total, r_total)
}

/// ROUGE-L F1 (longest common subsequence).
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c: Vec<&str> = candidate.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    let lcs = lcs_len(&c, &r);
    f1(lcs, c.len(), r.len())
}

fn lcs_len(a: &[&str], b: &[&str]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        for j in 1..=b.len() {
            cur[j] = if a[i - 1] == b[j - 1] {
                prev[j - 1] + 1
            } else {
                prev[j].max(cur[j - 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Token-sequence variant: exact-id ROUGE-L over raw token ids (for the
/// byte-level model where whitespace tokenization is meaningless).
pub fn rouge_l_ids(candidate: &[u32], reference: &[u32]) -> f64 {
    let c: Vec<String> = candidate.iter().map(|t| t.to_string()).collect();
    let r: Vec<String> = reference.iter().map(|t| t.to_string()).collect();
    let cs: Vec<&str> = c.iter().map(|s| s.as_str()).collect();
    let rs: Vec<&str> = r.iter().map(|s| s.as_str()).collect();
    f1(lcs_len(&cs, &rs), cs.len(), rs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_one() {
        assert!((rouge_n("a b c d", "a b c d", 1) - 1.0).abs() < 1e-12);
        assert!((rouge_n("a b c d", "a b c d", 2) - 1.0).abs() < 1e-12);
        assert!((rouge_l("a b c d", "a b c d") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(rouge_n("a b", "c d", 1), 0.0);
        assert_eq!(rouge_l("a b", "c d"), 0.0);
    }

    #[test]
    fn rouge1_known_value() {
        // cand: the cat sat / ref: the cat ate -> overlap 2, P=2/3, R=2/3
        let s = rouge_n("the cat sat", "the cat ate", 1);
        assert!((s - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_l_subsequence_not_substring() {
        // LCS("a x b y c", "a b c") = 3
        let s = rouge_l("a x b y c", "a b c");
        let expect = f1(3, 5, 3);
        assert!((s - expect).abs() < 1e-12);
    }

    #[test]
    fn clipped_counts() {
        // candidate repeats "a" 4x but reference has it twice
        let s = rouge_n("a a a a", "a a b b", 1);
        assert!((s - f1(2, 4, 4)).abs() < 1e-12);
    }

    #[test]
    fn ids_variant() {
        assert!((rouge_l_ids(&[1, 2, 3], &[1, 2, 3]) - 1.0).abs() < 1e-12);
        assert!(rouge_l_ids(&[1, 9, 2, 8, 3], &[1, 2, 3]) > 0.7);
        assert_eq!(rouge_l_ids(&[], &[1]), 0.0);
    }
}
