//! H2O oracle policy (simulator-only).
//!
//! H2O (Zhang et al. 2023) scores tokens by cumulative attention — exactly
//! the signal FlashAttention/PagedAttention never materialize, which is why
//! the paper excludes it from the deployable baselines (§5.2). The
//! simulator knows every token's true attention mass, so we expose H2O as
//! an *oracle upper bound*: heavy hitters + recent window, scored on truth.

use crate::eviction::{top_k_ascending, Decision, EvictionPolicy, KillList, PrefillScores};
use crate::kvcache::SeqCache;

pub struct H2oOracle {
    /// true importance by original position (the sim's latent w).
    importances: Vec<f64>,
    /// recent-window fraction of the budget (H2O keeps recency too).
    pub recent_frac: f64,
}

impl H2oOracle {
    pub fn new(importances: Vec<f64>) -> Self {
        H2oOracle { importances, recent_frac: 0.25 }
    }

    fn imp(&self, pos: usize) -> f64 {
        self.importances.get(pos).copied().unwrap_or(1e-6)
    }
}

impl EvictionPolicy for H2oOracle {
    fn name(&self) -> &'static str {
        "h2o_oracle"
    }

    fn structured(&self) -> bool {
        false
    }

    fn prefill_keep(&self, scores: &PrefillScores, budget: usize) -> Vec<usize> {
        let len = scores.len;
        if len <= budget {
            return (0..len).collect();
        }
        let recent = ((budget as f64 * self.recent_frac) as usize).min(budget);
        let hh_budget = budget - recent;
        // heavy hitters by TRUE importance over the non-recent prefix
        let head = len - recent;
        let truth: Vec<f32> = (0..head).map(|i| self.imp(i) as f32).collect();
        let mut keep = top_k_ascending(&truth, hh_budget);
        keep.extend(head..len);
        keep
    }

    fn post_append(&self, cache: &SeqCache, budget: usize) -> Decision {
        let live = cache.live_tokens();
        if live <= budget {
            return Decision::Keep;
        }
        let newest = cache.next_position().saturating_sub(1);
        let recent_cut = newest.saturating_sub((budget as f64 * self.recent_frac) as u32);
        let mut worst: Option<((usize, usize), f64)> = None;
        let mut kills = KillList::new();
        let mut over = live - budget;
        // kill the lowest-truth non-recent tokens
        let mut tokens: Vec<(usize, usize, u32)> = cache
            .live_token_list()
            .iter()
            .map(|&(bi, off, pos, _)| (bi, off, pos))
            .filter(|&(_, _, pos)| pos < recent_cut)
            .collect();
        tokens.sort_by(|a, b| self.imp(a.2 as usize).total_cmp(&self.imp(b.2 as usize)));
        for (bi, off, _) in tokens {
            if over == 0 {
                break;
            }
            kills.push(bi, off);
            over -= 1;
        }
        let _ = &mut worst;
        if kills.is_empty() {
            Decision::Keep
        } else {
            Decision::KillTokens(kills)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_keeps_heavy_hitters() {
        let mut imp = vec![0.01; 100];
        imp[10] = 5.0;
        imp[40] = 4.0;
        let o = H2oOracle::new(imp);
        let scores = PrefillScores {
            channels: [vec![0.0; 100], vec![0.0; 100], vec![0.0; 100]],
            len: 100,
        };
        let keep = o.prefill_keep(&scores, 20);
        assert!(keep.contains(&10));
        assert!(keep.contains(&40));
        assert!(keep.contains(&99), "recent window kept");
        assert_eq!(keep.len(), 20);
    }

    #[test]
    fn oracle_decode_kills_lowest_truth() {
        let mut imp = vec![1.0; 8];
        imp[2] = 1e-6;
        let o = H2oOracle::new(imp);
        let mut c = SeqCache::new(4, 4);
        c.load_prefill(&(0..8).map(|i| (i, [0.0; 3])).collect::<Vec<_>>(), 8);
        c.ensure_block();
        c.append([0.0; 3]);
        match o.post_append(&c, 8) {
            Decision::KillTokens(ts) => assert_eq!(ts, vec![(0, 2)]),
            d => panic!("{d:?}"),
        }
    }
}
