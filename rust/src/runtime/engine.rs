//! PJRT execution engine: compiles HLO-text artifacts on the CPU client
//! (lazily, cached) and owns the per-model weight literals.
//!
//! Single-threaded by design: the serving event loop owns the Engine; the
//! TCP frontend talks to it over channels (see `server/`). This mirrors the
//! vLLM split between the scheduler/worker process and the API server.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::{GraphInfo, Manifest};
use super::weights;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Per-model weights as DEVICE-RESIDENT buffers, uploaded once.
    /// (Also the workaround for an xla-crate 0.1.6 shim leak: `execute`
    /// with Literal args leaks its internal literal->buffer conversions
    /// ~0.7 MB/call; `execute_b` with self-managed PjRtBuffers does not —
    /// see EXPERIMENTS.md §Perf.)
    model_weights: RefCell<HashMap<String, Rc<Vec<xla::PjRtBuffer>>>>,
    /// compile wall-times per graph, for EXPERIMENTS.md §Perf
    compile_ms: RefCell<HashMap<String, f64>>,
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT platform={} devices={} kernel_impl={}",
            client.platform_name(),
            client.device_count(),
            manifest.kernel_impl
        );
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            model_weights: RefCell::new(HashMap::new()),
            compile_ms: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) a graph artifact.
    pub fn executable(&self, g: &GraphInfo) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&g.name) {
            return Ok(e.clone());
        }
        let t0 = std::time::Instant::now();
        let path = g.path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("PJRT compile of {}", g.name))?,
        );
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        log::debug!("compiled {} in {ms:.0} ms", g.name);
        self.compile_ms.borrow_mut().insert(g.name.clone(), ms);
        self.exes.borrow_mut().insert(g.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Per-model weights as device-resident buffers in ABI order (uploaded
    /// once, cached for the engine's lifetime).
    pub fn weights(&self, model: &str) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        if let Some(w) = self.model_weights.borrow().get(model) {
            return Ok(w.clone());
        }
        let info = self.manifest.model(model)?;
        let tensors = weights::load(&info.weights_file)?;
        let mut bufs = Vec::with_capacity(info.weight_names.len());
        for (name, shape) in info.weight_names.iter().zip(&info.weight_shapes) {
            let t = tensors
                .get(name)
                .with_context(|| format!("weights file missing tensor {name}"))?;
            anyhow::ensure!(
                &t.shape == shape,
                "tensor {name}: manifest shape {shape:?} != file shape {:?}",
                t.shape
            );
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, shape, None)
                    .with_context(|| format!("uploading weight {name}"))?,
            );
        }
        let rc = Rc::new(bufs);
        self.model_weights
            .borrow_mut()
            .insert(model.to_string(), rc.clone());
        Ok(rc)
    }

    /// Upload a host literal to a device buffer.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute a graph whose entry takes (runtime inputs ++ weights) and
    /// returns a tuple; decomposes the tuple to host literals.
    ///
    /// Inputs are uploaded to self-managed device buffers and executed via
    /// `execute_b` (the Literal-arg `execute` path in xla 0.1.6 leaks its
    /// internal conversions).
    pub fn run(
        &self,
        g: &GraphInfo,
        runtime_inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(g)?;
        let w = self.weights(&g.model)?;
        let ibufs: Vec<xla::PjRtBuffer> = runtime_inputs
            .iter()
            .map(|l| self.upload(l))
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(ibufs.len() + w.len());
        args.extend(ibufs.iter());
        args.extend(w.iter());
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .with_context(|| format!("executing {}", g.name))?;
        let first = &out[0][0];
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    pub fn compile_times_ms(&self) -> HashMap<String, f64> {
        self.compile_ms.borrow().clone()
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(data.len() == numel, "shape {shape:?} != {} elems", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(data.len() == numel, "shape {shape:?} != {} elems", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar i32 literal.
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::from(v)
}
