//! Deterministic fault injection for the decode stack.
//!
//! [`FaultyBackend`] wraps any [`DecodeBackend`] and injects faults driven
//! by a seeded, scriptable [`FaultPlan`]: per-request/per-step transient
//! and terminal decode errors, whole-batch failures, `snapshot()`
//! refusals, `restore`/`grow_bucket` failures, and injected slow rounds.
//! Tests, the CLI (`schedule --faults SPEC` / `serve --faults SPEC`) and
//! CI all replay identical fault schedules, so every recovery invariant
//! the scheduler claims (bit-identical recompute-and-replay, exact arena
//! drain, bounded retries) is provable under *faults*, not just under
//! memory pressure.
//!
//! ## Targeting model
//!
//! `decode_batch` receives anonymous `(sequence, token)` entries, so the
//! wrapper assigns every successfully prefilled sequence a **lane**:
//! a 1-based counter in prefill order (on a fresh scheduler this is
//! submission order). The lane and a per-lane **attempt** counter (how
//! many decode attempts this lane has been fed, including faulted ones)
//! ride inside [`FaultSeq`] and survive swap-to-host via
//! [`FaultSnapshot`]; a recompute readmission re-prefills and therefore
//! gets a fresh lane — exactly like a brand-new request, which is what a
//! recompute is to the backend. A fault verdict is a pure function of
//! `(seed, lane, attempt)` plus the rule list, so the schedule replays
//! identically regardless of batch composition or interleaving.
//!
//! Under the multi-worker engine (`scheduler::engine`) every worker owns
//! its OWN `FaultyBackend` built from a clone of the one plan, so lane
//! numbering is **per-worker-stable**: each worker's lanes count ITS
//! prefills from 1, unaffected by what other workers admit. A plan
//! therefore describes the same per-worker schedule at any worker count;
//! which requests land on which lanes shifts with placement, which is
//! why cross-worker-count bit-identity is only claimed for transient,
//! in-budget faults (recovery is lossless wherever it strikes).
//!
//! ## Spec grammar (comma-separated, e.g. `"transient@r2s4,batch@6"`)
//!
//! | clause            | meaning                                            |
//! |-------------------|----------------------------------------------------|
//! | `transient@rLsA`  | transient decode error for lane L at attempt A     |
//! | `transient@rLsA+` | ... at every attempt >= A                          |
//! | `terminal@rLsA`   | terminal decode error for lane L at attempt A      |
//! | `terminal@rLsA+`  | ... at every attempt >= A                          |
//! | `batch@N`         | Nth `decode_batch` call fails wholesale (transient)|
//! | `nosnap`          | refuse every `snapshot()` (forces recompute)       |
//! | `nosnap@rL`       | refuse `snapshot()` for lane L only                |
//! | `norestore@K`     | first K `restore` calls fail                       |
//! | `nogrow@K`        | first K `grow_bucket` calls fail                   |
//! | `slow@Nx<us>`     | Nth `decode_batch` call sleeps `<us>` microseconds |
//! | `seed=S`          | seed for the probabilistic clauses                 |
//! | `ptransient=P`    | P permille transient fault chance per attempt      |
//! | `pterminal=P`     | P permille terminal fault chance per attempt       |
//!
//! A plan-less wrapper ([`FaultyBackend::passthrough`]) adds one branch
//! and one `Vec` rebuild per round — the `fault_passthrough` row in
//! `micro_hotpath` pins that at ~zero via `tools/bench_gate.py`.

use anyhow::Result;

use crate::eviction::EvictionPolicy;
use crate::kvcache::{BlockAlloc, BlockManager, SeqCache};
use crate::scheduler::backend::{
    BackendError, DecodeBackend, HostSnapshot, Prefilled, PrefillStep, Restored,
};
use crate::scheduler::Request;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Rule {
    /// Decode fault for `lane` at attempt `attempt` (or every attempt
    /// `>= attempt` when `from` is set).
    DecodeAt { lane: u64, attempt: u64, from: bool, terminal: bool },
    /// The `call`th `decode_batch` call fails wholesale (every entry gets
    /// a transient error; the inner backend is never invoked, so no
    /// sequence state moves — a retry is lossless by construction).
    BatchFail { call: u64 },
    /// Refuse `snapshot()` (for one lane, or for everyone).
    NoSnap { lane: Option<u64> },
    /// Fail the first `first` `restore` calls.
    FailRestores { first: u64 },
    /// Fail the first `first` `grow_bucket` calls.
    FailGrows { first: u64 },
    /// Sleep `micros` before serving the `call`th `decode_batch` call.
    Slow { call: u64, micros: u64 },
}

/// What kind of decode fault a verdict resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    Transient,
    Terminal,
}

/// Immutable, replayable fault schedule. Build one with the fluent
/// methods (tests) or [`FaultPlan::parse`] (CLI/CI spec strings); hand it
/// to [`FaultyBackend::new`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    seed: u64,
    /// Permille chance of a transient decode fault per (lane, attempt).
    p_transient: u32,
    /// Permille chance of a terminal decode fault per (lane, attempt).
    p_terminal: u32,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse the comma-separated spec grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for raw in spec.split(',') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(rest) = clause.strip_prefix("transient@") {
                let (lane, attempt, from) = parse_lane_step(rest)?;
                plan.rules.push(Rule::DecodeAt { lane, attempt, from, terminal: false });
            } else if let Some(rest) = clause.strip_prefix("terminal@") {
                let (lane, attempt, from) = parse_lane_step(rest)?;
                plan.rules.push(Rule::DecodeAt { lane, attempt, from, terminal: true });
            } else if let Some(rest) = clause.strip_prefix("batch@") {
                plan.rules.push(Rule::BatchFail { call: parse_u64(rest)? });
            } else if clause == "nosnap" {
                plan.rules.push(Rule::NoSnap { lane: None });
            } else if let Some(rest) = clause.strip_prefix("nosnap@r") {
                plan.rules.push(Rule::NoSnap { lane: Some(parse_u64(rest)?) });
            } else if let Some(rest) = clause.strip_prefix("norestore@") {
                plan.rules.push(Rule::FailRestores { first: parse_u64(rest)? });
            } else if let Some(rest) = clause.strip_prefix("nogrow@") {
                plan.rules.push(Rule::FailGrows { first: parse_u64(rest)? });
            } else if let Some(rest) = clause.strip_prefix("slow@") {
                let (call, micros) = rest
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("slow clause wants N x micros: {clause:?}"))?;
                plan.rules.push(Rule::Slow {
                    call: parse_u64(call)?,
                    micros: parse_u64(micros)?,
                });
            } else if let Some(rest) = clause.strip_prefix("seed=") {
                plan.seed = parse_u64(rest)?;
            } else if let Some(rest) = clause.strip_prefix("ptransient=") {
                plan.p_transient = parse_permille(rest)?;
            } else if let Some(rest) = clause.strip_prefix("pterminal=") {
                plan.p_terminal = parse_permille(rest)?;
            } else {
                anyhow::bail!("unknown fault clause {clause:?}");
            }
        }
        Ok(plan)
    }

    pub fn transient_at(mut self, lane: u64, attempt: u64) -> Self {
        self.rules.push(Rule::DecodeAt { lane, attempt, from: false, terminal: false });
        self
    }

    /// Transient decode fault on every attempt `>= attempt` of `lane`
    /// (the poison-request shape the circuit breaker quarantines).
    pub fn transient_from(mut self, lane: u64, attempt: u64) -> Self {
        self.rules.push(Rule::DecodeAt { lane, attempt, from: true, terminal: false });
        self
    }

    pub fn terminal_at(mut self, lane: u64, attempt: u64) -> Self {
        self.rules.push(Rule::DecodeAt { lane, attempt, from: false, terminal: true });
        self
    }

    pub fn terminal_from(mut self, lane: u64, attempt: u64) -> Self {
        self.rules.push(Rule::DecodeAt { lane, attempt, from: true, terminal: true });
        self
    }

    /// The `call`th `decode_batch` call (1-based) fails wholesale.
    pub fn batch_fail_at(mut self, call: u64) -> Self {
        self.rules.push(Rule::BatchFail { call });
        self
    }

    /// Refuse every `snapshot()`: all preemption victims recompute.
    pub fn refuse_snapshots(mut self) -> Self {
        self.rules.push(Rule::NoSnap { lane: None });
        self
    }

    pub fn refuse_snapshots_for(mut self, lane: u64) -> Self {
        self.rules.push(Rule::NoSnap { lane: Some(lane) });
        self
    }

    /// Fail the first `first` `restore` calls (the scheduler falls back
    /// to recompute-and-replay).
    pub fn fail_restores(mut self, first: u64) -> Self {
        self.rules.push(Rule::FailRestores { first });
        self
    }

    /// Fail the first `first` `grow_bucket` calls.
    pub fn fail_grows(mut self, first: u64) -> Self {
        self.rules.push(Rule::FailGrows { first });
        self
    }

    /// Sleep `micros` before the `call`th `decode_batch` call.
    pub fn slow_round(mut self, call: u64, micros: u64) -> Self {
        self.rules.push(Rule::Slow { call, micros });
        self
    }

    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Permille chance of a transient decode fault on each attempt.
    pub fn p_transient(mut self, permille: u32) -> Self {
        self.p_transient = permille.min(1000);
        self
    }

    /// Permille chance of a terminal decode fault on each attempt.
    pub fn p_terminal(mut self, permille: u32) -> Self {
        self.p_terminal = permille.min(1000);
        self
    }

    /// Pure decode-fault verdict for one `(lane, attempt)` — rules first
    /// (terminal rules dominate transient ones on the same step), then
    /// the seeded permille draws. No state: replay-deterministic.
    fn verdict(&self, lane: u64, attempt: u64) -> Option<Fault> {
        let mut hit: Option<Fault> = None;
        for rule in &self.rules {
            if let Rule::DecodeAt { lane: l, attempt: a, from, terminal } = rule {
                let applies = *l == lane && if *from { attempt >= *a } else { attempt == *a };
                if applies {
                    if *terminal {
                        return Some(Fault::Terminal);
                    }
                    hit = Some(Fault::Transient);
                }
            }
        }
        if hit.is_some() {
            return hit;
        }
        if self.p_terminal > 0 {
            let h = splitmix64(self.seed ^ (lane << 20) ^ attempt ^ 0x7e72);
            if (h % 1000) < self.p_terminal as u64 {
                return Some(Fault::Terminal);
            }
        }
        if self.p_transient > 0 {
            let h = splitmix64(self.seed ^ (lane << 20) ^ attempt);
            if (h % 1000) < self.p_transient as u64 {
                return Some(Fault::Transient);
            }
        }
        None
    }

    fn refuses_snapshot(&self, lane: u64) -> bool {
        self.rules
            .iter()
            .any(|r| matches!(r, Rule::NoSnap { lane: l } if l.map_or(true, |l| l == lane)))
    }

    fn restore_budget(&self) -> u64 {
        self.rules
            .iter()
            .filter_map(|r| match r {
                Rule::FailRestores { first } => Some(*first),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    fn grow_budget(&self) -> u64 {
        self.rules
            .iter()
            .filter_map(|r| match r {
                Rule::FailGrows { first } => Some(*first),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    fn slow_micros(&self, call: u64) -> u64 {
        self.rules
            .iter()
            .filter_map(|r| match r {
                Rule::Slow { call: c, micros } if *c == call => Some(*micros),
                _ => None,
            })
            .sum()
    }

    fn batch_fails(&self, call: u64) -> bool {
        self.rules
            .iter()
            .any(|r| matches!(r, Rule::BatchFail { call: c } if *c == call))
    }
}

fn parse_u64(s: &str) -> Result<u64> {
    s.trim()
        .parse::<u64>()
        .map_err(|_| anyhow::anyhow!("expected a number, got {s:?}"))
}

fn parse_permille(s: &str) -> Result<u32> {
    let v = parse_u64(s)?;
    anyhow::ensure!(v <= 1000, "permille out of range: {v}");
    Ok(v as u32)
}

/// Parse the `rLsA[+]` lane/step form, e.g. `r2s4` or `r2s4+`.
fn parse_lane_step(s: &str) -> Result<(u64, u64, bool)> {
    let rest = s
        .strip_prefix('r')
        .ok_or_else(|| anyhow::anyhow!("expected rLsA, got {s:?}"))?;
    let (lane, rest) = rest
        .split_once('s')
        .ok_or_else(|| anyhow::anyhow!("expected rLsA, got {s:?}"))?;
    let (attempt, from) = match rest.strip_suffix('+') {
        Some(head) => (head, true),
        None => (rest, false),
    };
    let attempt = parse_u64(attempt)?;
    anyhow::ensure!(attempt >= 1, "attempts are 1-based");
    Ok((parse_u64(lane)?, attempt, from))
}

/// Per-sequence wrapper state: the inner backend's sequence plus the
/// fault-targeting identity (lane) and decode-attempt counter.
pub struct FaultSeq<S> {
    inner: S,
    lane: u64,
    attempts: u64,
}

impl<S> FaultSeq<S> {
    /// Fault-targeting lane of this sequence (1-based prefill order).
    pub fn lane(&self) -> u64 {
        self.lane
    }
}

/// Snapshot wrapper: carries the lane/attempt identity through
/// swap-to-host so a restored sequence keeps its fault schedule.
pub struct FaultSnapshot<S> {
    inner: S,
    lane: u64,
    attempts: u64,
}

impl<S: HostSnapshot> HostSnapshot for FaultSnapshot<S> {
    fn host_bytes(&self) -> usize {
        self.inner.host_bytes()
    }

    fn arena_blocks(&self) -> usize {
        self.inner.arena_blocks()
    }
}

/// Running tally of injected faults (observability for the CLI summary).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub transient: u64,
    pub terminal: u64,
    pub batch_failures: u64,
    pub snapshot_refusals: u64,
    pub restore_failures: u64,
    pub grow_failures: u64,
}

impl FaultCounts {
    pub fn total(&self) -> u64 {
        self.transient
            + self.terminal
            + self.batch_failures
            + self.snapshot_refusals
            + self.restore_failures
            + self.grow_failures
    }
}

/// A [`DecodeBackend`] decorator injecting the faults a [`FaultPlan`]
/// scripts. With no plan loaded it is a pure passthrough.
pub struct FaultyBackend<B: DecodeBackend> {
    inner: B,
    plan: Option<FaultPlan>,
    next_lane: u64,
    batch_calls: u64,
    restore_calls: u64,
    grow_calls: u64,
    transient_injected: u64,
    terminal_injected: u64,
    batch_failures: u64,
    snapshot_refusals: std::cell::Cell<u64>,
    restore_failures: u64,
    grow_failures: u64,
}

impl<B: DecodeBackend> FaultyBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> FaultyBackend<B> {
        FaultyBackend {
            inner,
            plan: Some(plan),
            next_lane: 0,
            batch_calls: 0,
            restore_calls: 0,
            grow_calls: 0,
            transient_injected: 0,
            terminal_injected: 0,
            batch_failures: 0,
            snapshot_refusals: std::cell::Cell::new(0),
            restore_failures: 0,
            grow_failures: 0,
        }
    }

    /// Wrapper with no plan: every call delegates untouched (the
    /// `fault_passthrough` bench row pins this at ~zero overhead).
    pub fn passthrough(inner: B) -> FaultyBackend<B> {
        let mut b = Self::new(inner, FaultPlan::new());
        b.plan = None;
        b
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    /// Lane-stamp the outcome of a chunked-prefill step: a completed
    /// chunked prefill claims its lane at `Done` (the moment the sequence
    /// becomes live), exactly where the one-shot path claims it at
    /// `Ready` — so lane numbering stays prefill-order regardless of how
    /// the compute was sliced.
    fn wrap_step(
        &mut self,
        step: PrefillStep<B::Seq, B::PrefillJob>,
    ) -> PrefillStep<FaultSeq<B::Seq>, B::PrefillJob> {
        match step {
            PrefillStep::More(job) => PrefillStep::More(job),
            PrefillStep::Done { seq, logits } => {
                self.next_lane += 1;
                PrefillStep::Done {
                    seq: FaultSeq { inner: seq, lane: self.next_lane, attempts: 0 },
                    logits,
                }
            }
            PrefillStep::OutOfMemory => PrefillStep::OutOfMemory,
        }
    }

    /// Injected-fault tallies so far.
    pub fn fault_counts(&self) -> FaultCounts {
        FaultCounts {
            transient: self.transient_injected,
            terminal: self.terminal_injected,
            batch_failures: self.batch_failures,
            snapshot_refusals: self.snapshot_refusals.get(),
            restore_failures: self.restore_failures,
            grow_failures: self.grow_failures,
        }
    }
}

impl<B: DecodeBackend> DecodeBackend for FaultyBackend<B> {
    type Seq = FaultSeq<B::Seq>;

    type Snapshot = FaultSnapshot<B::Snapshot>;

    type PrefillPlan = B::PrefillPlan;

    type PrefillJob = B::PrefillJob;

    fn set_prefix_cache(&mut self, enabled: bool) {
        self.inner.set_prefix_cache(enabled);
    }

    fn prefill_claim(&self, arena: &BlockManager, req: &Request, page_size: usize) -> usize {
        self.inner.prefill_claim(arena, req, page_size)
    }

    fn prefill_claim_planned(
        &self,
        arena: &BlockManager,
        req: &Request,
        page_size: usize,
    ) -> (usize, Option<Self::PrefillPlan>) {
        self.inner.prefill_claim_planned(arena, req, page_size)
    }

    fn prepare_round(&mut self, seq: &mut Self::Seq) -> BlockAlloc {
        self.inner.prepare_round(&mut seq.inner)
    }

    fn prefill(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Result<Prefilled<Self::Seq>> {
        self.prefill_planned(arena, prompt, budget, policy, None)
    }

    fn prefill_planned(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
        plan: Option<&Self::PrefillPlan>,
    ) -> Result<Prefilled<Self::Seq>> {
        match self.inner.prefill_planned(arena, prompt, budget, policy, plan)? {
            Prefilled::Ready { seq, logits } => {
                self.next_lane += 1;
                Ok(Prefilled::Ready {
                    seq: FaultSeq { inner: seq, lane: self.next_lane, attempts: 0 },
                    logits,
                })
            }
            Prefilled::OutOfMemory => Ok(Prefilled::OutOfMemory),
        }
    }

    fn prefill_begin(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
        plan: Option<&Self::PrefillPlan>,
        chunk: usize,
    ) -> Result<Option<PrefillStep<Self::Seq, Self::PrefillJob>>> {
        match self
            .inner
            .prefill_begin(arena, prompt, budget, policy, plan, chunk)?
        {
            Some(step) => Ok(Some(self.wrap_step(step))),
            None => Ok(None),
        }
    }

    fn prefill_advance(
        &mut self,
        job: Self::PrefillJob,
        chunk: usize,
    ) -> Result<PrefillStep<Self::Seq, Self::PrefillJob>> {
        let step = self.inner.prefill_advance(job, chunk)?;
        Ok(self.wrap_step(step))
    }

    fn cache(seq: &Self::Seq) -> &SeqCache {
        B::cache(&seq.inner)
    }

    fn cache_mut(seq: &mut Self::Seq) -> &mut SeqCache {
        B::cache_mut(&mut seq.inner)
    }

    fn grow_bucket(&mut self, seq: &mut Self::Seq) -> Result<()> {
        if let Some(plan) = &self.plan {
            if self.grow_calls < plan.grow_budget() {
                self.grow_calls += 1;
                self.grow_failures += 1;
                anyhow::bail!("injected grow_bucket failure (call {})", self.grow_calls);
            }
            self.grow_calls += 1;
        }
        self.inner.grow_bucket(&mut seq.inner)
    }

    fn snapshot(&self, seq: &Self::Seq) -> Option<Self::Snapshot> {
        if let Some(plan) = &self.plan {
            if plan.refuses_snapshot(seq.lane) {
                self.snapshot_refusals.set(self.snapshot_refusals.get() + 1);
                return None;
            }
        }
        self.inner.snapshot(&seq.inner).map(|inner| FaultSnapshot {
            inner,
            lane: seq.lane,
            attempts: seq.attempts,
        })
    }

    fn restore(
        &mut self,
        arena: &BlockManager,
        snap: &Self::Snapshot,
    ) -> Result<Restored<Self::Seq>> {
        if let Some(plan) = &self.plan {
            if self.restore_calls < plan.restore_budget() {
                self.restore_calls += 1;
                self.restore_failures += 1;
                anyhow::bail!("injected restore failure (call {})", self.restore_calls);
            }
            self.restore_calls += 1;
        }
        match self.inner.restore(arena, &snap.inner)? {
            Restored::Ready(inner) => Ok(Restored::Ready(FaultSeq {
                inner,
                lane: snap.lane,
                attempts: snap.attempts,
            })),
            Restored::OutOfMemory => Ok(Restored::OutOfMemory),
        }
    }

    fn attention_feedback(&self, seq: &Self::Seq) -> Option<crate::eviction::AttnFeedback> {
        // observability channel, never a fault-injection target: a fault
        // here could not be distinguished from a backend without one
        self.inner.attention_feedback(&seq.inner)
    }

    fn shared_prefix_depth(&self, arena: &BlockManager, prompt: &[u32]) -> usize {
        self.inner.shared_prefix_depth(arena, prompt)
    }

    fn decode_batch(
        &mut self,
        batch: &mut [(&mut Self::Seq, u32)],
    ) -> Vec<std::result::Result<Vec<f32>, BackendError>> {
        let Some(plan) = &self.plan else {
            // passthrough: one Vec rebuild to strip the wrapper layer
            let mut inner: Vec<(&mut B::Seq, u32)> =
                batch.iter_mut().map(|e| (&mut e.0.inner, e.1)).collect();
            return self.inner.decode_batch(&mut inner);
        };
        self.batch_calls += 1;
        let call = self.batch_calls;

        let micros = plan.slow_micros(call);
        if micros > 0 {
            std::thread::sleep(std::time::Duration::from_micros(micros));
        }
        let fail_whole_batch = plan.batch_fails(call);

        // One pass: bump attempt counters, decide per-entry verdicts, and
        // collect the surviving entries for the inner dispatch. Faulted
        // entries never reach the inner backend, so their sequence state
        // does not move — a retry replays losslessly by construction.
        let mut slots: Vec<Option<std::result::Result<Vec<f32>, BackendError>>> =
            Vec::with_capacity(batch.len());
        let mut inner_batch: Vec<(&mut B::Seq, u32)> = Vec::with_capacity(batch.len());
        let mut injected_transient = 0u64;
        let mut injected_terminal = 0u64;
        for e in batch.iter_mut() {
            e.0.attempts += 1;
            let (lane, attempt) = (e.0.lane, e.0.attempts);
            if fail_whole_batch {
                injected_transient += 1;
                slots.push(Some(Err(BackendError::transient(anyhow::anyhow!(
                    "injected batch failure (call {call}, lane {lane})"
                )))));
                continue;
            }
            match plan.verdict(lane, attempt) {
                Some(Fault::Transient) => {
                    injected_transient += 1;
                    slots.push(Some(Err(BackendError::transient(anyhow::anyhow!(
                        "injected transient fault (lane {lane}, attempt {attempt})"
                    )))));
                }
                Some(Fault::Terminal) => {
                    injected_terminal += 1;
                    slots.push(Some(Err(BackendError::terminal(anyhow::anyhow!(
                        "injected terminal fault (lane {lane}, attempt {attempt})"
                    )))));
                }
                None => {
                    slots.push(None);
                    inner_batch.push((&mut e.0.inner, e.1));
                }
            }
        }
        self.transient_injected += injected_transient;
        self.terminal_injected += injected_terminal;
        if fail_whole_batch {
            self.batch_failures += 1;
        }

        let inner_results = if inner_batch.is_empty() {
            Vec::new()
        } else {
            self.inner.decode_batch(&mut inner_batch)
        };
        let mut it = inner_results.into_iter();
        slots
            .into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| {
                    it.next().expect("inner backend returned one result per entry")
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::make_policy;
    use crate::runtime::model_runner::argmax;
    use crate::runtime::SimBackend;

    fn prefilled(
        be: &mut FaultyBackend<SimBackend>,
        arena: &BlockManager,
        prompt: &[u32],
    ) -> (FaultSeq<crate::runtime::SimSeq>, u32) {
        let Prefilled::Ready { seq, logits } = be
            .prefill(arena, prompt, 64, make_policy("paged").unwrap())
            .unwrap()
        else {
            panic!("unexpected OOM")
        };
        (seq, argmax(&logits))
    }

    #[test]
    fn parse_roundtrips_the_builder_forms() {
        let parsed = FaultPlan::parse(
            "transient@r2s4, terminal@r3s1+, batch@6, nosnap, nosnap@r5, \
             norestore@2, nogrow@1, slow@3x500, seed=9, ptransient=15, pterminal=1",
        )
        .unwrap();
        let built = FaultPlan::new()
            .transient_at(2, 4)
            .terminal_from(3, 1)
            .batch_fail_at(6)
            .refuse_snapshots()
            .refuse_snapshots_for(5)
            .fail_restores(2)
            .fail_grows(1)
            .slow_round(3, 500)
            .seeded(9)
            .p_transient(15)
            .p_terminal(1);
        assert_eq!(parsed, built);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::new());
        assert!(FaultPlan::parse("transient@r2").is_err());
        assert!(FaultPlan::parse("chaos@everywhere").is_err());
        assert!(FaultPlan::parse("transient@r1s0").is_err(), "attempts are 1-based");
        assert!(FaultPlan::parse("ptransient=2000").is_err());
        assert!(FaultPlan::parse("slow@3").is_err());
    }

    #[test]
    fn verdicts_are_pure_and_seed_sensitive() {
        let p = FaultPlan::new().seeded(7).p_transient(200);
        let a: Vec<_> = (1..=64).map(|s| p.verdict(3, s)).collect();
        let b: Vec<_> = (1..=64).map(|s| p.verdict(3, s)).collect();
        assert_eq!(a, b, "verdicts are a pure function of (seed, lane, attempt)");
        assert!(a.iter().any(|v| v.is_some()), "200 permille over 64 draws must hit");
        assert!(a.iter().any(|v| v.is_none()));
        let q = FaultPlan::new().seeded(8).p_transient(200);
        let c: Vec<_> = (1..=64).map(|s| q.verdict(3, s)).collect();
        assert_ne!(a, c, "a different seed reshuffles the schedule");
        // rules: terminal dominates transient on the same (lane, attempt)
        let r = FaultPlan::new().transient_at(1, 2).terminal_at(1, 2);
        assert_eq!(r.verdict(1, 2), Some(Fault::Terminal));
        assert_eq!(r.verdict(1, 1), None);
        assert_eq!(r.verdict(2, 2), None);
    }

    #[test]
    fn scripted_decode_fault_skips_inner_state() {
        let arena = BlockManager::new(4096);
        let prompt: Vec<u32> = (0..24).collect();
        // twin A: passthrough
        let mut clean = FaultyBackend::passthrough(SimBackend::new(4));
        let (mut cseq, mut ctok) = prefilled(&mut clean, &arena, &prompt);
        // twin B: attempt 2 faults transiently; the retry (attempt 3, same
        // fed token) must land on identical state
        let mut faulty =
            FaultyBackend::new(SimBackend::new(4), FaultPlan::new().transient_at(1, 2));
        let (mut fseq, mut ftok) = prefilled(&mut faulty, &arena, &prompt);
        assert_eq!(ctok, ftok);
        for step in 0..6 {
            while !FaultyBackend::<SimBackend>::cache_mut(&mut cseq).ensure_block() {
                clean.grow_bucket(&mut cseq).unwrap();
            }
            while !FaultyBackend::<SimBackend>::cache_mut(&mut fseq).ensure_block() {
                faulty.grow_bucket(&mut fseq).unwrap();
            }
            // clean twin advances unconditionally
            let mut b = [(&mut cseq, ctok)];
            let r = clean.decode_batch(&mut b).pop().unwrap().unwrap();
            ctok = argmax(&r);
            // faulty twin: the injected attempt errors, then succeeds
            let mut b = [(&mut fseq, ftok)];
            let mut r = faulty.decode_batch(&mut b).pop().unwrap();
            if step == 1 {
                let err = r.expect_err("attempt 2 must fault");
                assert!(err.is_transient());
                let mut b = [(&mut fseq, ftok)];
                r = faulty.decode_batch(&mut b).pop().unwrap();
            }
            ftok = argmax(&r.expect("non-injected attempts succeed"));
            assert_eq!(ctok, ftok, "retry is lossless: twins stay bit-identical");
        }
        assert_eq!(faulty.fault_counts().transient, 1);
        assert_eq!(clean.fault_counts().total(), 0);
    }

    #[test]
    fn snapshot_restore_and_grow_faults_fire() {
        let arena = BlockManager::new(4096);
        let prompt: Vec<u32> = (0..16).collect();
        let plan = FaultPlan::new().refuse_snapshots().fail_restores(1).fail_grows(1);
        let mut be = FaultyBackend::new(SimBackend::new(4), plan);
        let (mut seq, _tok) = prefilled(&mut be, &arena, &prompt);
        assert!(be.snapshot(&seq).is_none(), "nosnap refuses the snapshot");
        assert!(be.grow_bucket(&mut seq).is_err(), "first grow fails");
        assert!(be.grow_bucket(&mut seq).is_ok(), "budget exhausted, grows recover");
        let counts = be.fault_counts();
        assert_eq!((counts.snapshot_refusals, counts.grow_failures), (1, 1));

        // per-lane refusal + restore budget, on a plan that CAN snapshot
        let plan = FaultPlan::new().refuse_snapshots_for(2).fail_restores(1);
        let mut be = FaultyBackend::new(SimBackend::new(4), plan);
        let (seq1, _) = prefilled(&mut be, &arena, &prompt);
        let (seq2, _) = prefilled(&mut be, &arena, &prompt);
        assert_eq!((seq1.lane(), seq2.lane()), (1, 2), "lanes count prefills");
        let snap = be.snapshot(&seq1).expect("lane 1 snapshots fine");
        assert!(be.snapshot(&seq2).is_none(), "lane 2 is refused");
        drop((seq1, seq2));
        assert!(be.restore(&arena, &snap).is_err(), "first restore fails");
        let Restored::Ready(restored) = be.restore(&arena, &snap).unwrap() else {
            panic!("second restore succeeds")
        };
        assert_eq!(restored.lane(), 1, "restore keeps the fault-targeting lane");
    }
}
