//! Deterministic in-process decode backend (no PJRT, always built).
//!
//! Drives the REAL paged cache + eviction machinery (`SeqCache` allocating
//! from the shared `BlockManager` arena, real `EvictionPolicy` decisions)
//! under a toy "language model" whose next-token logits are a pure
//! function of the token history fed so far. Two consequences the
//! scheduler tests lean on:
//!
//!   * greedy decode is bit-deterministic, and **independent of physical
//!     block layout** — so a preempted sequence that is readmitted and
//!     recomputed (prefill + replay of its produced tokens) continues with
//!     exactly the tokens an uncontended run produces;
//!   * decoding a batch is equivalent to decoding each sequence alone —
//!     the batched-round scheduler can be pinned bit-identical to the old
//!     one-sequence-at-a-time loop.
//!
//! Importance scores are a deterministic hash of (position, token), so
//! eviction pressure is realistic (blocks fill, evict, fragment) without
//! any RNG state that replay could desynchronize.

use anyhow::Result;

use crate::eviction::{make_policy, AttnFeedback, Decision, EvictionPolicy, PrefillScores};
use crate::kvcache::{prefix_block_hashes, BlockAlloc, BlockManager, KvSnapshot, SeqCache};
use crate::scheduler::backend::{
    static_prefill_claim, BackendError, DecodeBackend, HostSnapshot, Prefilled, PrefillStep,
    Restored,
};
use crate::scheduler::Request;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Fold one fed token into the history hash.
fn fold(state: u64, tok: u32) -> u64 {
    splitmix64(state ^ (tok as u64 + 1))
}

/// One in-flight generation on the sim backend.
pub struct SimSeq {
    pub cache: SeqCache,
    pub budget: usize,
    pub policy: Box<dyn EvictionPolicy>,
    pub prompt_len: usize,
    /// Rolling hash of every token fed so far (prompt, then decode feeds).
    state: u64,
}

/// Swap-to-host snapshot of a [`SimSeq`]: the full cache state plus the
/// backend's continuation state (the rolling history hash) and the policy
/// identity. Policies are stateless configuration (`make_policy` rebuilds
/// them by name; their per-sequence statistics live in the cache's
/// `CacheStats`, carried inside the [`KvSnapshot`]), so the snapshot is
/// complete: a restored sequence decodes bit-identically to one that was
/// never suspended.
pub struct SimSnapshot {
    kv: KvSnapshot,
    budget: usize,
    prompt_len: usize,
    policy: &'static str,
    state: u64,
}

impl HostSnapshot for SimSnapshot {
    fn host_bytes(&self) -> usize {
        self.kv.host_bytes() + std::mem::size_of::<Self>()
    }

    fn arena_blocks(&self) -> usize {
        self.kv.n_blocks()
    }
}

/// Reusable artifact of the admission-time claim scan: the policy's kept
/// `(position, scores)` stream and the per-entry content keys. Depends
/// only on the immutable `(prompt, budget, policy)` triple, so the
/// scheduler parks it on the queue entry and the admitted prefill loads
/// it instead of re-running the O(prompt) scorer + keep scan.
pub struct SimPrefillPlan {
    entries: Vec<(u32, [f32; 3])>,
    keys: Vec<u64>,
}

/// Carried state of an in-progress chunked prefill.
///
/// The sim's "forward pass" over the prompt is the rolling history-hash
/// fold, so a chunk folds the next `chunk` prompt tokens; the kept-entry
/// stream (the policy scan over the FULL prompt — identical to what the
/// one-shot path loads) rides along, and the packed cache is materialized
/// only at the final chunk (claim-at-completion). An abandoned job
/// therefore holds no arena blocks and drops for free, and the finished
/// sequence is bit-identical to a one-shot prefill by construction: the
/// fold order, the entry stream and the bulk load are the same code.
pub struct SimPrefillJob {
    arena: BlockManager,
    entries: Vec<(u32, [f32; 3])>,
    keys: Vec<u64>,
    prompt: Vec<u32>,
    /// Prompt tokens folded into `state` so far.
    folded: usize,
    state: u64,
    budget: usize,
    policy: Box<dyn EvictionPolicy>,
}

impl SimPrefillJob {
    /// Prompt tokens still unprocessed — what the remaining chunks cover.
    pub fn remaining(&self) -> usize {
        self.prompt.len() - self.folded
    }
}

pub struct SimBackend {
    pub page_size: usize,
    /// Toy vocabulary size (logit vector length).
    pub vocab: usize,
    /// Prefix caching: prefills publish their full prompt blocks into the
    /// arena's content-hash index and map leading hits by reference
    /// instead of allocating. Off by default so direct backend users keep
    /// the historical accounting; the scheduler flips it from
    /// `SchedConfig::prefix_cache`.
    pub prefix_cache: bool,
    /// Times `prefill_claim` actually recomputed its O(prompt) estimate
    /// (scorer replay + hash chain). The scheduler memoizes the result on
    /// the queue entry against the prefix-index epoch, so gated admission
    /// retries must NOT bump this — pinned in `tests/api_session.rs`.
    claim_calls: std::cell::Cell<u64>,
    /// Times the O(prompt) prefill policy scan (`kept_entries`) actually
    /// ran. The claim scan's result rides to the admitted prefill as a
    /// [`SimPrefillPlan`], so one admission costs ONE scan, not two —
    /// pinned in `tests/multi_worker.rs`.
    policy_scans: std::cell::Cell<u64>,
}

impl SimBackend {
    pub fn new(page_size: usize) -> SimBackend {
        SimBackend {
            page_size,
            vocab: 211,
            prefix_cache: false,
            claim_calls: std::cell::Cell::new(0),
            policy_scans: std::cell::Cell::new(0),
        }
    }

    /// How many times the admission claim estimate was recomputed.
    pub fn claim_calls(&self) -> u64 {
        self.claim_calls.get()
    }

    /// How many times the O(prompt) prefill policy scan actually ran
    /// (claim-time scans included; plan-reusing prefills excluded).
    pub fn policy_scans(&self) -> u64 {
        self.policy_scans.get()
    }

    /// Deterministic importance channels for the token at `pos`. Channel
    /// semantics match the live system (0: higher = keep; 1/2: lower =
    /// keep); values are uniform-ish in [0, 1].
    fn tok_scores(pos: u32, tok: u32) -> [f32; 3] {
        let h = splitmix64(((pos as u64) << 32) | tok as u64);
        [
            ((h & 0xffff) as f32) / 65535.0,
            (((h >> 16) & 0xffff) as f32) / 65535.0,
            (((h >> 32) & 0xffff) as f32) / 65535.0,
        ]
    }

    /// Per-entry content key for the prefix-block hash chain: binds the
    /// raw token identity into the chain, so two prompts hash equal
    /// exactly when their kept (position, token) streams are equal.
    fn content_key(pos: u32, tok: u32) -> u64 {
        splitmix64(((pos as u64) << 32) ^ (tok as u64) ^ 0x00c0_ffee_5eed_0001)
    }

    /// Run the (deterministic) scorer over `prompt`, apply the policy's
    /// prefill eviction, and return the packed entry stream plus the
    /// per-entry content keys the prefix index hashes over.
    fn kept_entries(
        &self,
        prompt: &[u32],
        budget: usize,
        policy: &dyn EvictionPolicy,
    ) -> (Vec<(u32, [f32; 3])>, Vec<u64>) {
        self.policy_scans.set(self.policy_scans.get() + 1);
        let len = prompt.len();
        let mut channels = [
            Vec::with_capacity(len),
            Vec::with_capacity(len),
            Vec::with_capacity(len),
        ];
        for (i, &t) in prompt.iter().enumerate() {
            let sc = Self::tok_scores(i as u32, t);
            for (c, ch) in channels.iter_mut().enumerate() {
                ch.push(sc[c]);
            }
        }
        let scores = PrefillScores { channels, len };
        let keep = policy.prefill_keep(&scores, budget);
        let mut entries = Vec::with_capacity(keep.len());
        let mut keys = Vec::with_capacity(keep.len());
        for &i in &keep {
            entries.push((
                i as u32,
                [
                    scores.channels[0][i],
                    scores.channels[1][i],
                    scores.channels[2][i],
                ],
            ));
            keys.push(Self::content_key(i as u32, prompt[i]));
        }
        (entries, keys)
    }

    /// The sequence's attention-feedback vector: the pure positional-mass
    /// model ([`crate::sim::positional_mass`]) sampled over every original
    /// position up to the decode horizon. Depends only on the sequence's
    /// own position counter — never on scheduling order, batch composition
    /// or worker count — so feedback-consuming policies stay as replayable
    /// as proxy-driven ones (preempt/recompute lands on the same vector).
    fn feedback_for(seq: &SimSeq) -> AttnFeedback {
        let horizon = seq.cache.next_position();
        AttnFeedback {
            mass: (0..horizon).map(|p| crate::sim::positional_mass(p, horizon)).collect(),
        }
    }

    /// Logits for the current history hash: a deterministic sub-0.5 floor
    /// everywhere plus a 1.0 winner at `mix(state) % vocab`.
    fn logits(&self, state: u64) -> Vec<f32> {
        let winner = (splitmix64(state) % self.vocab as u64) as usize;
        let mut v = Vec::with_capacity(self.vocab);
        for i in 0..self.vocab {
            v.push(((splitmix64(state ^ ((i as u64) << 17)) & 0xfff) as f32) / 8192.0);
        }
        v[winner] = 1.0;
        v
    }
}

impl DecodeBackend for SimBackend {
    type Seq = SimSeq;

    type Snapshot = SimSnapshot;

    type PrefillPlan = SimPrefillPlan;

    type PrefillJob = SimPrefillJob;

    fn set_prefix_cache(&mut self, enabled: bool) {
        self.prefix_cache = enabled;
    }

    /// Admission charge with prefix hits subtracted: replays the policy's
    /// prefill keep decision (cheap and deterministic here) and counts the
    /// leading kept blocks already published in the arena's index — those
    /// pages are pinned by refcount, not re-claimed.
    fn prefill_claim(&self, arena: &BlockManager, req: &Request, page_size: usize) -> usize {
        self.prefill_claim_planned(arena, req, page_size).0
    }

    /// The full admission charge AND the scan artifact that priced it:
    /// the kept-entry stream rides back to the scheduler so the admitted
    /// prefill loads it instead of re-running the policy scan.
    fn prefill_claim_planned(
        &self,
        arena: &BlockManager,
        req: &Request,
        page_size: usize,
    ) -> (usize, Option<SimPrefillPlan>) {
        self.claim_calls.set(self.claim_calls.get() + 1);
        let full = static_prefill_claim(req, page_size);
        let Ok(policy) = make_policy(&req.policy) else {
            return (full, None); // unknown policy fails at admission anyway
        };
        let (entries, keys) = self.kept_entries(&req.prompt, req.budget, policy.as_ref());
        let claim = if self.prefix_cache {
            let hashes = prefix_block_hashes(self.page_size, &entries, &keys);
            full.saturating_sub(arena.count_leading_hits(&hashes))
        } else {
            full
        };
        (claim, Some(SimPrefillPlan { entries, keys }))
    }

    /// Unstructured policies hole-punch tokens inside pages every step:
    /// copy-on-write their shared prefix pages now, while the scheduler
    /// can still preempt on `ArenaDry`. Structured policies share safely
    /// (whole-page eviction just drops a reference) and skip this.
    fn prepare_round(&mut self, seq: &mut SimSeq) -> BlockAlloc {
        if seq.policy.kills_tokens() {
            if let Err(blocked) = seq.cache.unshare_shared_blocks() {
                return blocked;
            }
        }
        BlockAlloc::Ready
    }

    fn prefill(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Result<Prefilled<SimSeq>> {
        self.prefill_planned(arena, prompt, budget, policy, None)
    }

    /// Prefill, loading the claim scan's kept-entry stream from `plan`
    /// when the scheduler kept one — the plan is a pure memo of
    /// `kept_entries(prompt, budget, policy)`, so both paths build a
    /// bit-identical sequence.
    fn prefill_planned(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
        plan: Option<&SimPrefillPlan>,
    ) -> Result<Prefilled<SimSeq>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(budget >= self.page_size, "budget below one page");
        let bs = self.page_size;
        let len = prompt.len();
        let scanned;
        let (entries, keys): (&[(u32, [f32; 3])], &[u64]) = match plan {
            Some(p) => (&p.entries, &p.keys),
            None => {
                scanned = self.kept_entries(prompt, budget, policy.as_ref());
                (&scanned.0, &scanned.1)
            }
        };
        anyhow::ensure!(!entries.is_empty(), "policy kept zero tokens");

        // bucket: kept tokens plus two pages of eviction-oscillation slack
        let bucket = (entries.len() + bs - 1) / bs + 2;
        let mut cache = SeqCache::new_shared(bs, bucket, arena);
        let loaded = if self.prefix_cache {
            cache.try_load_prefill_cached(entries, keys, len as u32).map(|_| ())
        } else {
            cache.try_load_prefill(entries, len as u32)
        };
        if loaded.is_err() {
            // dropping `cache` returns any partially claimed blocks
            // (shared hit pages merely lose this sequence's reference)
            return Ok(Prefilled::OutOfMemory);
        }
        let mut state = 0u64;
        for &t in prompt {
            state = fold(state, t);
        }
        let logits = self.logits(state);
        Ok(Prefilled::Ready {
            seq: SimSeq { cache, budget, policy, prompt_len: len, state },
            logits,
        })
    }

    /// Start a chunked prefill: run the (full-prompt) policy scan exactly
    /// as the one-shot path would, then fold the first `chunk` tokens.
    /// The cache is NOT allocated yet — see [`SimPrefillJob`].
    fn prefill_begin(
        &mut self,
        arena: &BlockManager,
        prompt: &[u32],
        budget: usize,
        policy: Box<dyn EvictionPolicy>,
        plan: Option<&SimPrefillPlan>,
        chunk: usize,
    ) -> Result<Option<PrefillStep<SimSeq, SimPrefillJob>>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(budget >= self.page_size, "budget below one page");
        let (entries, keys) = match plan {
            Some(p) => (p.entries.clone(), p.keys.clone()),
            None => self.kept_entries(prompt, budget, policy.as_ref()),
        };
        anyhow::ensure!(!entries.is_empty(), "policy kept zero tokens");
        let job = SimPrefillJob {
            arena: arena.clone(),
            entries,
            keys,
            prompt: prompt.to_vec(),
            folded: 0,
            state: 0,
            budget,
            policy,
        };
        self.prefill_advance(job, chunk).map(Some)
    }

    /// Fold up to `chunk` more prompt tokens; on the final chunk, claim
    /// and bulk-load the packed cache exactly like the one-shot prefill.
    fn prefill_advance(
        &mut self,
        mut job: SimPrefillJob,
        chunk: usize,
    ) -> Result<PrefillStep<SimSeq, SimPrefillJob>> {
        let take = job.remaining().min(chunk.max(1));
        for &t in &job.prompt[job.folded..job.folded + take] {
            job.state = fold(job.state, t);
        }
        job.folded += take;
        if job.folded < job.prompt.len() {
            return Ok(PrefillStep::More(job));
        }

        let bs = self.page_size;
        let len = job.prompt.len();
        // bucket: kept tokens plus two pages of eviction-oscillation slack
        let bucket = (job.entries.len() + bs - 1) / bs + 2;
        let mut cache = SeqCache::new_shared(bs, bucket, &job.arena);
        let loaded = if self.prefix_cache {
            cache
                .try_load_prefill_cached(&job.entries, &job.keys, len as u32)
                .map(|_| ())
        } else {
            cache.try_load_prefill(&job.entries, len as u32)
        };
        if loaded.is_err() {
            // dropping `cache` returns any partially claimed blocks
            return Ok(PrefillStep::OutOfMemory);
        }
        let logits = self.logits(job.state);
        Ok(PrefillStep::Done {
            seq: SimSeq {
                cache,
                budget: job.budget,
                policy: job.policy,
                prompt_len: len,
                state: job.state,
            },
            logits,
        })
    }

    fn cache(seq: &SimSeq) -> &SeqCache {
        &seq.cache
    }

    fn cache_mut(seq: &mut SimSeq) -> &mut SeqCache {
        &mut seq.cache
    }

    fn grow_bucket(&mut self, seq: &mut SimSeq) -> Result<()> {
        let nb = seq.cache.capacity_blocks() + 2;
        seq.cache.grow(nb);
        Ok(())
    }

    fn snapshot(&self, seq: &SimSeq) -> Option<SimSnapshot> {
        Some(SimSnapshot {
            kv: seq.cache.snapshot(),
            budget: seq.budget,
            prompt_len: seq.prompt_len,
            policy: seq.policy.name(),
            state: seq.state,
        })
    }

    fn restore(&mut self, arena: &BlockManager, snap: &SimSnapshot) -> Result<Restored<SimSeq>> {
        let cache = match SeqCache::restore_from(&snap.kv, arena) {
            Ok(c) => c,
            Err(BlockAlloc::ArenaDry) => return Ok(Restored::OutOfMemory),
            Err(e) => anyhow::bail!("snapshot restore failed: {e:?}"),
        };
        let policy = make_policy(snap.policy)?;
        Ok(Restored::Ready(SimSeq {
            cache,
            budget: snap.budget,
            policy,
            prompt_len: snap.prompt_len,
            state: snap.state,
        }))
    }

    fn attention_feedback(&self, seq: &SimSeq) -> Option<AttnFeedback> {
        Some(Self::feedback_for(seq))
    }

    fn shared_prefix_depth(&self, arena: &BlockManager, prompt: &[u32]) -> usize {
        if !self.prefix_cache || prompt.is_empty() {
            return 0;
        }
        // The full-prompt identity pack: what a keep-everything prefill
        // would publish. Published leading blocks come from policies that
        // kept their head tokens verbatim (always true for prompts within
        // budget), so leading-hit counting against this pack is exact for
        // the shared-prefix workloads the autotuner cares about and a
        // conservative 0 otherwise. A pure read — nothing is claimed.
        let mut entries = Vec::with_capacity(prompt.len());
        let mut keys = Vec::with_capacity(prompt.len());
        for (i, &t) in prompt.iter().enumerate() {
            entries.push((i as u32, Self::tok_scores(i as u32, t)));
            keys.push(Self::content_key(i as u32, t));
        }
        let hashes = prefix_block_hashes(self.page_size, &entries, &keys);
        arena.count_leading_hits(&hashes)
    }

    fn decode_batch(
        &mut self,
        batch: &mut [(&mut SimSeq, u32)],
    ) -> Vec<std::result::Result<Vec<f32>, BackendError>> {
        batch
            .iter_mut()
            .map(|entry| {
                let seq: &mut SimSeq = &mut *entry.0;
                let tok = entry.1;
                if seq.cache.last_block_full() {
                    // a missing write slot is a scheduler contract breach,
                    // not a device hiccup: retrying cannot fix it
                    return Err(BackendError::terminal(anyhow::anyhow!(
                        "no write slot reserved for decode"
                    )));
                }
                seq.state = fold(seq.state, tok);
                let pos = seq.cache.next_position();
                seq.cache.append(Self::tok_scores(pos, tok));
                // the O(horizon) feedback vector is assembled only for
                // policies that consume it; every other policy's decode
                // step is byte-for-byte the pre-feedback hot path
                let fb = seq.policy.wants_feedback().then(|| Self::feedback_for(seq));
                let decision = match &fb {
                    Some(f) => seq.policy.post_append_feedback(&seq.cache, seq.budget, Some(f)),
                    None => seq.policy.post_append(&seq.cache, seq.budget),
                };
                match decision {
                    Decision::Keep => {}
                    Decision::EvictBlock(i) => seq.cache.evict_block(i),
                    Decision::KillTokens(ts) => {
                        for (bi, off) in ts {
                            seq.cache.kill_token(bi, off);
                        }
                    }
                }
                Ok(self.logits(seq.state))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::make_policy;
    use crate::runtime::model_runner::argmax;

    fn drive(prompt: &[u32], gen: usize, budget: usize, policy: &str) -> Vec<u32> {
        let arena = BlockManager::new(4096);
        let mut be = SimBackend::new(4);
        let pre = be
            .prefill(&arena, prompt, budget, make_policy(policy).unwrap())
            .unwrap();
        let Prefilled::Ready { mut seq, logits } = pre else {
            panic!("unexpected OOM")
        };
        let mut tok = argmax(&logits);
        let mut out = Vec::new();
        for _ in 0..gen {
            out.push(tok);
            while !seq.cache.ensure_block() {
                be.grow_bucket(&mut seq).unwrap();
            }
            let mut b = [(&mut seq, tok)];
            let r = be.decode_batch(&mut b).pop().unwrap().unwrap();
            tok = argmax(&r);
        }
        out
    }

    #[test]
    fn decode_is_deterministic_and_policy_invariant_tokens() {
        let prompt: Vec<u32> = (0..40).map(|i| (i * 7) % 100).collect();
        let a = drive(&prompt, 16, 16, "paged");
        let b = drive(&prompt, 16, 16, "paged");
        assert_eq!(a, b, "same history must produce the same tokens");
        // logits depend only on history, so a different eviction policy
        // (different cache layout) still yields the same greedy tokens
        let c = drive(&prompt, 16, 16, "streaming");
        assert_eq!(a, c, "tokens are layout-independent by construction");
    }

    #[test]
    fn budgeted_policy_keeps_cache_bounded() {
        let prompt: Vec<u32> = (0..64).map(|i| i as u32).collect();
        let arena = BlockManager::new(4096);
        let mut be = SimBackend::new(4);
        let Prefilled::Ready { mut seq, logits } = be
            .prefill(&arena, &prompt, 16, make_policy("paged").unwrap())
            .unwrap()
        else {
            panic!("OOM")
        };
        let mut tok = argmax(&logits);
        for _ in 0..32 {
            while !seq.cache.ensure_block() {
                be.grow_bucket(&mut seq).unwrap();
            }
            let mut b = [(&mut seq, tok)];
            tok = argmax(&be.decode_batch(&mut b).pop().unwrap().unwrap());
            assert!(seq.cache.live_tokens() <= 16 + 4, "budget + one page");
            seq.cache.check_invariants().unwrap();
        }
    }

    #[test]
    fn feedback_policies_decode_the_same_tokens() {
        // logits depend only on token history, so attention-guided
        // policies (different cache layouts, different evictions) still
        // produce the paged baseline's greedy tokens — the structural fact
        // that makes `--policy auto` digests policy- and worker-invariant
        let prompt: Vec<u32> = (0..40).map(|i| (i * 7) % 100).collect();
        let base = drive(&prompt, 16, 16, "paged");
        for pol in ["self_attn", "self_attn_token", "attention_gate"] {
            assert_eq!(base, drive(&prompt, 16, 16, pol), "{pol}");
        }
    }

    #[test]
    fn attention_feedback_covers_the_horizon() {
        let arena = BlockManager::new(4096);
        let mut be = SimBackend::new(4);
        let prompt: Vec<u32> = (0..24).map(|i| i as u32).collect();
        let Prefilled::Ready { seq, .. } = be
            .prefill(&arena, &prompt, 64, make_policy("self_attn").unwrap())
            .unwrap()
        else {
            panic!("OOM")
        };
        let fb = be.attention_feedback(&seq).unwrap();
        assert_eq!(fb.len(), seq.cache.next_position() as usize);
        assert!((0..fb.len()).all(|p| fb.mass_at(p) > 0.0));
        // out-of-range positions read as zero mass, by contract
        assert_eq!(fb.mass_at(fb.len() + 5), 0.0);
    }

    #[test]
    fn shared_prefix_depth_probe_reads_the_index() {
        let arena = BlockManager::new(4096);
        let mut be = SimBackend::new(4);
        let prompt: Vec<u32> = (0..32).map(|i| i as u32).collect();
        assert_eq!(be.shared_prefix_depth(&arena, &prompt), 0, "prefix cache off");
        be.set_prefix_cache(true);
        assert_eq!(be.shared_prefix_depth(&arena, &prompt), 0, "nothing published yet");
        let Prefilled::Ready { seq, .. } = be
            .prefill(&arena, &prompt, 64, make_policy("paged").unwrap())
            .unwrap()
        else {
            panic!("OOM")
        };
        // within-budget prefill kept the whole prompt: its published pack
        // IS the identity pack, so the probe sees every leading block
        assert_eq!(be.shared_prefix_depth(&arena, &prompt), 32 / 4);
        // a diverging prompt shares nothing
        let other: Vec<u32> = (0..32).map(|i| (i + 100) as u32).collect();
        assert_eq!(be.shared_prefix_depth(&arena, &other), 0);
        drop(seq);
    }

    #[test]
    fn prefill_reports_oom_on_tiny_arena() {
        let arena = BlockManager::new(1);
        let mut be = SimBackend::new(4);
        let prompt: Vec<u32> = (0..32).map(|i| i as u32).collect();
        match be
            .prefill(&arena, &prompt, 32, make_policy("paged").unwrap())
            .unwrap()
        {
            Prefilled::OutOfMemory => {}
            Prefilled::Ready { .. } => panic!("1-block arena cannot hold 32 tokens"),
        }
        assert_eq!(arena.used(), 0, "failed prefill leaks no blocks");
    }
}
