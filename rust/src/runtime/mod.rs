//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` + `*.weights.bin`) and executes them on the CPU PJRT
//! client from the serving hot path. Python never runs here.

pub mod engine;
pub mod manifest;
pub mod model_runner;
pub mod weights;

pub use engine::Engine;
pub use manifest::{GraphInfo, GraphKind, Manifest, ModelInfo};
pub use model_runner::{ModelRunner, Sequence, StepOutput};
