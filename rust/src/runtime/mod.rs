//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` + `*.weights.bin`) and executes them on the CPU PJRT
//! client from the serving hot path. Python never runs here.
//!
//! The manifest and weights parsers are pure host code and always built;
//! the PJRT execution engine and the model runner's execute paths need the
//! XLA shared library and are gated behind the `xla` cargo feature
//! (off by default so a plain toolchain builds and tests the crate).

#[cfg(feature = "xla")]
pub mod engine;
pub mod faults;
pub mod manifest;
pub mod model_runner;
pub mod sim_backend;
pub mod weights;

#[cfg(feature = "xla")]
pub use engine::Engine;
pub use manifest::{GraphInfo, GraphKind, Manifest, ModelInfo};
#[cfg(feature = "xla")]
pub use model_runner::{ModelRunner, Sequence, StepOutput};
pub use faults::{FaultCounts, FaultPlan, FaultSeq, FaultSnapshot, FaultyBackend};
pub use sim_backend::{SimBackend, SimPrefillJob, SimPrefillPlan, SimSeq, SimSnapshot};
