//! Model execution against the paged KV cache: prefill -> host-side pack ->
//! bucketed decode loop, with the eviction policy applied after every step.
//!
//! The device-side cache literals round-trip through each decode call (the
//! graph scatters the new token and returns the updated cache); eviction
//! never touches them — it only rewrites the block table and validity mask,
//! which is the paper's central systems claim. Those two graph inputs are
//! borrowed straight out of `SeqCache`'s incrementally maintained buffers
//! (the incoming token's mask bit is staged in place for the literal build
//! and restored), so steady-state decode performs no heap allocation and
//! no buffer copy on the metadata path.
//!
//! Everything that executes through PJRT is gated behind the `xla` cargo
//! feature; [`argmax`] is pure host code and always available.

#[cfg(feature = "xla")]
mod pjrt {
    use anyhow::{bail, Context, Result};

    use crate::eviction::{
        aggregate_decode_scores, Decision, EvictionPolicy, PrefillScores,
    };
    use crate::kvcache::{BlockAlloc, BlockManager, SeqCache};
    use crate::runtime::engine::{lit_f32, lit_i32, scalar_i32, Engine};
    use crate::runtime::manifest::ModelInfo;
    use crate::scheduler::backend::{BackendError, DecodeBackend, NoSwap, Prefilled, Restored};

    pub struct ModelRunner<'e> {
        pub engine: &'e Engine,
        pub model: ModelInfo,
        pub page_size: usize,
    }

    /// One in-flight generation.
    pub struct Sequence {
        pub cache: SeqCache,
        k_lit: xla::Literal,
        v_lit: xla::Literal,
        pub budget: usize,
        pub policy: Box<dyn EvictionPolicy>,
        pub prompt_len: usize,
        pub generated: Vec<u32>,
        /// wall time spent inside PJRT execute for this sequence (perf metric)
        pub exec_seconds: f64,
    }

    pub struct StepOutput {
        pub logits: Vec<f32>,
        pub scores: [f32; 3],
    }

    impl<'e> ModelRunner<'e> {
        pub fn new(engine: &'e Engine, model: &str, page_size: usize) -> Result<Self> {
            let info = engine.manifest.model(model)?.clone();
            anyhow::ensure!(
                engine.manifest.page_sizes(model).contains(&page_size),
                "no decode artifacts for {model} @ page size {page_size}"
            );
            Ok(ModelRunner { engine, model: info, page_size })
        }

        /// Standalone prefill with a private single-tenant arena (one-shot
        /// generation, benches). See [`ModelRunner::prefill_shared`].
        pub fn prefill(
            &self,
            prompt: &[u32],
            budget: usize,
            policy: Box<dyn EvictionPolicy>,
        ) -> Result<(Sequence, Vec<f32>)> {
            match self.prefill_shared(None, prompt, budget, policy)? {
                Prefilled::Ready { seq, logits } => Ok((seq, logits)),
                Prefilled::OutOfMemory => bail!("private arena cannot be out of memory"),
            }
        }

        /// Run the prompt, apply prefill token eviction, pack the retained
        /// tokens into a paged cache allocated from `arena` (or a private
        /// arena when `None`). Returns `Prefilled::OutOfMemory` — with all
        /// partially claimed blocks returned — when the shared arena
        /// cannot hold the packed prompt.
        pub fn prefill_shared(
            &self,
            arena: Option<&BlockManager>,
            prompt: &[u32],
            budget: usize,
            policy: Box<dyn EvictionPolicy>,
        ) -> Result<Prefilled<Sequence>> {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            anyhow::ensure!(budget >= self.page_size, "budget below one page");
            let len = prompt.len();
            let g = self.engine.manifest.prefill_graph(&self.model.name, len)?;
            let p = g.seq_bucket;
            let mut toks = vec![0i32; p];
            for (i, t) in prompt.iter().enumerate() {
                toks[i] = *t as i32;
            }
            let t0 = std::time::Instant::now();
            let outs = self
                .engine
                .run(g, &[lit_i32(&toks, &[p])?, scalar_i32(len as i32)])?;
            let exec_s = t0.elapsed().as_secs_f64();
            let [logits_l, k_l, v_l, sc_l]: [xla::Literal; 4] = outs
                .try_into()
                .map_err(|_| anyhow::anyhow!("prefill returned wrong tuple arity"))?;
            let logits = logits_l.to_vec::<f32>()?;
            let sc_flat = sc_l.to_vec::<f32>()?;
            let scores =
                PrefillScores::from_graph_output(&sc_flat, self.model.n_layers, p, len);

            // --- prefill-phase token eviction (paper Alg. 2) ---
            let keep = policy.prefill_keep(&scores, budget);
            anyhow::ensure!(!keep.is_empty(), "policy kept zero tokens");

            // --- host-side pack into the paged layout ---
            let bs = self.page_size;
            let nb = self.initial_bucket_blocks(keep.len(), &policy)?;
            let (k_lit, v_lit) = self.pack_cache(&k_l, &v_l, &keep, p, nb)?;
            let mut cache = match arena {
                Some(a) => SeqCache::new_shared(bs, nb, a),
                None => SeqCache::new(bs, nb),
            };
            let entries: Vec<(u32, [f32; 3])> = keep
                .iter()
                .map(|&i| {
                    (
                        i as u32,
                        [
                            scores.channels[0][i],
                            scores.channels[1][i],
                            scores.channels[2][i],
                        ],
                    )
                })
                .collect();
            if cache.try_load_prefill(&entries, len as u32).is_err() {
                // dropping `cache` returns any partially claimed blocks
                return Ok(Prefilled::OutOfMemory);
            }
            let seq = Sequence {
                cache,
                k_lit,
                v_lit,
                budget,
                policy,
                prompt_len: len,
                generated: Vec::new(),
                exec_seconds: exec_s,
            };
            Ok(Prefilled::Ready { seq, logits })
        }

        /// One decode step: feed `token`, get next-token logits. Applies the
        /// eviction policy afterwards. Self-managing single-sequence path:
        /// grows the bucket on demand; a dry shared arena is an error here
        /// (the scheduler's reservation pass preempts before dispatching).
        pub fn decode_step(&self, seq: &mut Sequence, token: u32) -> Result<StepOutput> {
            let bs = self.page_size;
            loop {
                match seq.cache.try_ensure_block() {
                    BlockAlloc::Ready => break,
                    BlockAlloc::BucketFull => self.grow(seq)?,
                    BlockAlloc::ArenaDry => {
                        bail!("shared KV arena exhausted — scheduler must preempt")
                    }
                }
            }
            let write_slot = seq
                .cache
                .peek_write_slot()
                .context("no write slot after ensure_block")?;
            let nb = seq.cache.capacity_blocks();
            let g = self
                .engine
                .manifest
                .decode_graph(&self.model.name, bs, nb * bs)?;
            debug_assert_eq!(g.n_blocks, nb, "bucket/capacity drift");

            // Graph inputs come straight from the incrementally maintained
            // buffers: the table is borrowed as-is; the mask is borrowed
            // with the incoming token's slot staged live for just the
            // literal build (`append` commits it for real after the step),
            // so no host-side copy happens beyond the literal's own.
            let table_lit = lit_i32(seq.cache.block_table(nb), &[nb])?;
            let logical_slot = (seq.cache.n_blocks() - 1) * bs
                + seq.cache.blocks().last().unwrap().fill;
            let mask_lit = seq
                .cache
                .with_incoming_mask(nb, logical_slot, |m| lit_f32(m, &[nb, bs]))?;
            // this backend uploads both buffers whole; a device-resident
            // metadata backend would consume table_dirty()/mask_dirty() here
            seq.cache.clear_dirty();

            let pos = seq.cache.next_position() as i32;
            let inputs = [
                scalar_i32(token as i32),
                scalar_i32(pos),
                std::mem::replace(&mut seq.k_lit, xla::Literal::from(0f32)),
                std::mem::replace(&mut seq.v_lit, xla::Literal::from(0f32)),
                table_lit,
                scalar_i32(write_slot as i32),
                mask_lit,
            ];
            let t0 = std::time::Instant::now();
            let outs = self.engine.run(g, &inputs)?;
            seq.exec_seconds += t0.elapsed().as_secs_f64();
            let [logits_l, k_l, v_l, sc_l]: [xla::Literal; 4] = outs
                .try_into()
                .map_err(|_| anyhow::anyhow!("decode returned wrong tuple arity"))?;
            seq.k_lit = k_l;
            seq.v_lit = v_l;
            let logits = logits_l.to_vec::<f32>()?;
            let sc = aggregate_decode_scores(&sc_l.to_vec::<f32>()?, self.model.n_layers);

            seq.cache.append(sc);
            seq.generated.push(token);
            match seq.policy.post_append(&seq.cache, seq.budget) {
                Decision::Keep => {}
                Decision::EvictBlock(i) => seq.cache.evict_block(i),
                Decision::KillTokens(ts) => {
                    for (bi, off) in ts {
                        seq.cache.kill_token(bi, off);
                    }
                }
            }
            Ok(StepOutput { logits, scores: sc })
        }

        /// Initial decode bucket for a packed prompt: room for the retained
        /// tokens plus the eviction-oscillation slack (budget + 2 pages for
        /// bounded policies), or just prompt+1 page for FullCache which grows
        /// on demand.
        fn initial_bucket_blocks(
            &self,
            kept_tokens: usize,
            policy: &Box<dyn EvictionPolicy>,
        ) -> Result<usize> {
            let bs = self.page_size;
            let need_tokens = if policy.name() == "full" {
                kept_tokens + bs
            } else {
                kept_tokens.max(/* budget slack */ 0) + 2 * bs
            };
            let g = self
                .engine
                .manifest
                .decode_graph(&self.model.name, bs, need_tokens)?;
            Ok(g.n_blocks)
        }

        /// Bucket migration: move the cache literals into the next larger
        /// decode bucket (host roundtrip — rare; counted in CacheStats).
        fn grow(&self, seq: &mut Sequence) -> Result<()> {
            let bs = self.page_size;
            let old_nb = seq.cache.capacity_blocks();
            let max_tokens = self.engine.manifest.max_decode_tokens(&self.model.name, bs);
            if (old_nb + 1) * bs > max_tokens {
                bail!(
                    "cache exhausted: {} blocks @ page {bs} is the largest bucket \
                     (policy {} never evicts enough)",
                    old_nb,
                    seq.policy.name()
                );
            }
            let g = self
                .engine
                .manifest
                .decode_graph(&self.model.name, bs, (old_nb + 1) * bs)?;
            let new_nb = g.n_blocks;
            let l = self.model.n_layers;
            let hkv = self.model.n_kv_heads;
            let dh = self.model.d_head;
            for lit in [&mut seq.k_lit, &mut seq.v_lit] {
                let old = lit.to_vec::<f32>()?;
                let mut new = vec![0f32; l * hkv * new_nb * bs * dh];
                let chunk = bs * dh;
                for li in 0..l {
                    for h in 0..hkv {
                        for b in 0..old_nb {
                            let src = ((li * hkv + h) * old_nb + b) * chunk;
                            let dst = ((li * hkv + h) * new_nb + b) * chunk;
                            new[dst..dst + chunk].copy_from_slice(&old[src..src + chunk]);
                        }
                    }
                }
                *lit = lit_f32(&new, &[l, hkv, new_nb, bs, dh])?;
            }
            seq.cache.grow(new_nb);
            log::debug!("bucket grow {} -> {} blocks", old_nb, new_nb);
            Ok(())
        }

        /// Host-side pack (prefill -> paged layout): retained token j goes to
        /// physical slot (j / B, j % B). k/v literals are [L, Hkv, P, dh].
        fn pack_cache(
            &self,
            k_l: &xla::Literal,
            v_l: &xla::Literal,
            keep: &[usize],
            p: usize,
            nb: usize,
        ) -> Result<(xla::Literal, xla::Literal)> {
            let l = self.model.n_layers;
            let hkv = self.model.n_kv_heads;
            let dh = self.model.d_head;
            let bs = self.page_size;
            let kf = k_l.to_vec::<f32>()?;
            let vf = v_l.to_vec::<f32>()?;
            anyhow::ensure!(kf.len() == l * hkv * p * dh, "prefill K shape mismatch");
            let mut kc = vec![0f32; l * hkv * nb * bs * dh];
            let mut vc = vec![0f32; l * hkv * nb * bs * dh];
            for li in 0..l {
                for h in 0..hkv {
                    let src_base = (li * hkv + h) * p * dh;
                    let dst_base = (li * hkv + h) * nb * bs * dh;
                    for (j, &tok) in keep.iter().enumerate() {
                        let src = src_base + tok * dh;
                        let dst = dst_base + j * dh;
                        kc[dst..dst + dh].copy_from_slice(&kf[src..src + dh]);
                        vc[dst..dst + dh].copy_from_slice(&vf[src..src + dh]);
                    }
                }
            }
            Ok((
                lit_f32(&kc, &[l, hkv, nb, bs, dh])?,
                lit_f32(&vc, &[l, hkv, nb, bs, dh])?,
            ))
        }

        /// One padded batched dispatch for the whole running set, when the
        /// artifact matrix provides a `decode_batch` graph covering this
        /// (page size, context bucket, batch) cell. Every member sequence
        /// is first grown to the graph's common bucket so the stacked
        /// cache tensor is rectangular; lanes `>= batch.len()` are padding
        /// (all-zero validity masks, token 0). Returns `Ok(None)` when no
        /// batched graph exists and the caller should fall back to
        /// per-sequence dispatch.
        ///
        /// NOTE: this backend round-trips the per-sequence cache literals
        /// through the host to stack them; a device-resident batched cache
        /// (ROADMAP "device-resident KV metadata") removes that copy.
        fn try_decode_batch_fused(
            &self,
            batch: &mut [(&mut Sequence, u32)],
        ) -> Result<Option<Vec<std::result::Result<Vec<f32>, BackendError>>>> {
            let bs = self.page_size;
            let n = batch.len();
            let want_nb = batch
                .iter()
                .map(|(s, _)| s.cache.capacity_blocks())
                .max()
                .unwrap_or(1);
            let g = match self.engine.manifest.decode_batch_graph(
                &self.model.name,
                bs,
                want_nb * bs,
                n,
            ) {
                Some(g) => g,
                None => return Ok(None),
            };
            let nb = g.n_blocks;
            let lanes = g.batch;
            for (s, _) in batch.iter_mut() {
                while s.cache.capacity_blocks() < nb {
                    self.grow(s)?;
                }
                anyhow::ensure!(
                    s.cache.capacity_blocks() == nb,
                    "bucket ladder misaligned with batch graph ({} vs {nb})",
                    s.cache.capacity_blocks()
                );
            }
            let (l, hkv, dh) = (self.model.n_layers, self.model.n_kv_heads, self.model.d_head);
            let per = l * hkv * nb * bs * dh;
            let mut kf = vec![0f32; lanes * per];
            let mut vf = vec![0f32; lanes * per];
            let mut toks = vec![0i32; lanes];
            let mut poss = vec![0i32; lanes];
            let mut slots = vec![0i32; lanes];
            let mut tables = vec![0i32; lanes * nb];
            let mut masks = vec![0f32; lanes * nb * bs];
            for (j, (s, tok)) in batch.iter_mut().enumerate() {
                kf[j * per..(j + 1) * per].copy_from_slice(&s.k_lit.to_vec::<f32>()?);
                vf[j * per..(j + 1) * per].copy_from_slice(&s.v_lit.to_vec::<f32>()?);
                toks[j] = *tok as i32;
                poss[j] = s.cache.next_position() as i32;
                slots[j] = s
                    .cache
                    .peek_write_slot()
                    .context("no write slot reserved for batched decode")?
                    as i32;
                tables[j * nb..(j + 1) * nb].copy_from_slice(s.cache.block_table(nb));
                let logical_slot = (s.cache.n_blocks() - 1) * bs
                    + s.cache.blocks().last().unwrap().fill;
                s.cache.with_incoming_mask(nb, logical_slot, |m| {
                    masks[j * nb * bs..(j + 1) * nb * bs].copy_from_slice(m)
                });
            }
            let inputs = [
                lit_i32(&toks, &[lanes])?,
                lit_i32(&poss, &[lanes])?,
                lit_f32(&kf, &[lanes, l, hkv, nb, bs, dh])?,
                lit_f32(&vf, &[lanes, l, hkv, nb, bs, dh])?,
                lit_i32(&tables, &[lanes, nb])?,
                lit_i32(&slots, &[lanes])?,
                lit_f32(&masks, &[lanes, nb, bs])?,
            ];
            let t0 = std::time::Instant::now();
            let outs = self.engine.run(g, &inputs)?;
            let exec_s = t0.elapsed().as_secs_f64() / n as f64;
            let [logits_l, k_l, v_l, sc_l]: [xla::Literal; 4] = outs
                .try_into()
                .map_err(|_| anyhow::anyhow!("batched decode returned wrong tuple arity"))?;
            let logits_all = logits_l.to_vec::<f32>()?;
            let k_all = k_l.to_vec::<f32>()?;
            let v_all = v_l.to_vec::<f32>()?;
            let sc_all = sc_l.to_vec::<f32>()?;
            let vsize = self.model.vocab_size;
            anyhow::ensure!(logits_all.len() == lanes * vsize, "batched logits shape");
            // Convert-then-commit: finish every fallible conversion BEFORE
            // mutating any sequence, so an error anywhere leaves all lanes
            // untouched and the caller can safely fall back to
            // per-sequence dispatch.
            let mut converted = Vec::with_capacity(n);
            for j in 0..n {
                converted.push((
                    lit_f32(&k_all[j * per..(j + 1) * per], &[l, hkv, nb, bs, dh])?,
                    lit_f32(&v_all[j * per..(j + 1) * per], &[l, hkv, nb, bs, dh])?,
                ));
            }
            let mut results = Vec::with_capacity(n);
            for ((j, (s, tok)), (k_new, v_new)) in
                batch.iter_mut().enumerate().zip(converted)
            {
                s.k_lit = k_new;
                s.v_lit = v_new;
                s.exec_seconds += exec_s;
                s.cache.clear_dirty(); // buffers were uploaded whole above
                let sc =
                    aggregate_decode_scores(&sc_all[j * 3 * l..(j + 1) * 3 * l], l);
                s.cache.append(sc);
                s.generated.push(*tok);
                match s.policy.post_append(&s.cache, s.budget) {
                    Decision::Keep => {}
                    Decision::EvictBlock(i) => s.cache.evict_block(i),
                    Decision::KillTokens(ts) => {
                        for (bi, off) in ts {
                            s.cache.kill_token(bi, off);
                        }
                    }
                }
                results.push(Ok(logits_all[j * vsize..(j + 1) * vsize].to_vec()));
            }
            Ok(Some(results))
        }
    }

    impl<'e> DecodeBackend for ModelRunner<'e> {
        type Seq = Sequence;

        type Snapshot = NoSwap;

        // The PJRT claim estimate computes nothing a prefill could reuse.
        type PrefillPlan = ();

        // Chunked prefill unsupported: prefill_begin's default Ok(None)
        // routes the scheduler to the one-shot path.
        type PrefillJob = ();

        fn prefill(
            &mut self,
            arena: &BlockManager,
            prompt: &[u32],
            budget: usize,
            policy: Box<dyn EvictionPolicy>,
        ) -> Result<Prefilled<Sequence>> {
            ModelRunner::prefill_shared(self, Some(arena), prompt, budget, policy)
        }

        fn cache(seq: &Sequence) -> &SeqCache {
            &seq.cache
        }

        fn cache_mut(seq: &mut Sequence) -> &mut SeqCache {
            &mut seq.cache
        }

        fn grow_bucket(&mut self, seq: &mut Sequence) -> Result<()> {
            ModelRunner::grow(self, seq)
        }

        /// The runner's K/V literals stand in for device-resident buffers;
        /// downloading them on every preemption would defeat swapping's
        /// purpose, so this backend opts out and the scheduler keeps the
        /// recompute-on-readmission path for its victims. Swap support
        /// arrives with the device-resident batched cache (ROADMAP), where
        /// a single bounded copy per victim becomes meaningful.
        fn snapshot(&self, _seq: &Sequence) -> Option<NoSwap> {
            None
        }

        fn restore(
            &mut self,
            _arena: &BlockManager,
            _snap: &NoSwap,
        ) -> Result<Restored<Sequence>> {
            bail!("the PJRT backend never snapshots, so there is nothing to restore")
        }

        fn decode_batch(
            &mut self,
            batch: &mut [(&mut Sequence, u32)],
        ) -> Vec<std::result::Result<Vec<f32>, BackendError>> {
            // Prefer the single padded batched dispatch; fall back to
            // per-sequence dispatch when the artifact set has no batched
            // graph for this cell.
            if batch.len() > 1 {
                match self.try_decode_batch_fused(batch) {
                    Ok(Some(results)) => return results,
                    Ok(None) => {}
                    Err(e) => {
                        // The fused path commits nothing before erroring
                        // (convert-then-commit), so per-sequence dispatch
                        // below is a safe recovery — one bad lane must not
                        // retire the whole running set.
                        log::warn!(
                            "batched dispatch failed; falling back to \
                             per-sequence decode: {e:#}"
                        );
                    }
                }
            }
            batch
                .iter_mut()
                .map(|entry| {
                    self.decode_step(&mut *entry.0, entry.1)
                        .map(|o| o.logits)
                        // a PJRT execute failure may have committed partial
                        // per-lane state; no lossless retry exists here
                        .map_err(BackendError::terminal)
                })
                .collect()
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{ModelRunner, Sequence, StepOutput};

/// Greedy decode helper: index of the largest logit. Single fold over
/// `f32::total_cmp`; NaN logits are skipped outright so a poisoned logit
/// can never silently win (the old `>`-based scan returned index 0
/// whenever `logits[0]` was NaN). Ties keep the earliest index; an empty
/// or all-NaN slice returns 0.
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .fold(None::<(usize, f32)>, |best, (i, &v)| {
            if v.is_nan() {
                return best;
            }
            match best {
                Some((_, bv)) if bv.total_cmp(&v) != std::cmp::Ordering::Less => best,
                _ => Some((i, v)),
            }
        })
        .map_or(0, |(i, _)| i as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-3.0, -1.0, -2.0]), 1);
    }

    #[test]
    fn argmax_ties_keep_earliest() {
        assert_eq!(argmax(&[1.0, 7.0, 7.0, 7.0]), 1);
        assert_eq!(argmax(&[0.0, -0.0]), 0);
    }

    #[test]
    fn argmax_never_picks_nan() {
        assert_eq!(argmax(&[f32::NAN, 1.0, 3.0]), 2, "NaN at index 0 must not win");
        assert_eq!(argmax(&[2.0, f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN, 0.5]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0, "all-NaN degrades to 0");
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN, f32::INFINITY]), 2);
    }
}
