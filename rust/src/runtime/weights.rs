//! PEW1 weights container parser (written by python/compile/model.py;
//! format documented in DESIGN.md §7).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

pub fn load(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading weights {:?}", path.as_ref()))?;
    parse(&bytes)
}

pub fn parse(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > bytes.len() {
            bail!("truncated weights file at offset {off}");
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    };
    if take(&mut off, 4)? != b"PEW1" {
        bail!("bad magic (not a PEW1 weights file)");
    }
    let count = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        let name = std::str::from_utf8(take(&mut off, nlen)?)
            .context("bad tensor name")?
            .to_string();
        let dtype = take(&mut off, 1)?[0];
        if dtype != 0 {
            bail!("tensor {name}: only f32 (dtype 0) supported, got {dtype}");
        }
        let rank = take(&mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = take(&mut off, numel * 4)?;
        let mut data = vec![0f32; numel];
        for (i, c) in raw.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(c.try_into().unwrap());
        }
        out.insert(name.clone(), Tensor { name, shape, data });
    }
    if off != bytes.len() {
        bail!("{} trailing bytes in weights file", bytes.len() - off);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut b = b"PEW1".to_vec();
        b.extend((tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in tensors {
            b.extend((name.len() as u16).to_le_bytes());
            b.extend(name.as_bytes());
            b.push(0); // f32
            b.push(shape.len() as u8);
            for d in *shape {
                b.extend((*d as u32).to_le_bytes());
            }
            for x in *data {
                b.extend(x.to_le_bytes());
            }
        }
        b
    }

    #[test]
    fn roundtrip() {
        let bytes = enc(&[
            ("emb", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("norm", &[2], &[1.0, 1.0]),
        ]);
        let w = parse(&bytes).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w["emb"].shape, vec![2, 3]);
        assert_eq!(w["emb"].data[4], 5.0);
        assert_eq!(w["norm"].numel(), 2);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOPE").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut bytes = enc(&[("w", &[4], &[0.0; 4])]);
        bytes.truncate(bytes.len() - 2);
        assert!(parse(&bytes).is_err());
    }

    #[cfg_attr(not(feature = "xla"), ignore = "needs `make artifacts` (xla feature)")]
    #[test]
    fn loads_real_artifact() {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/sim-1b.weights.bin");
        let w = load(p).expect("run `make artifacts`");
        assert!(w.contains_key("emb"));
        assert!(w.contains_key("layer0.wq"));
        assert!(w.contains_key("head"));
        let total: usize = w.values().map(|t| t.numel()).sum();
        assert!(total > 50_000, "sim-1b should have >50k params, got {total}");
    }
}
