//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-tree JSON parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    Prefill,
    Decode,
    /// Batched decode: one dispatch stepping `batch` sequences, each with
    /// its own block table / validity mask / cache, padded to a common
    /// context bucket. Lowered by `python/compile/aot.py` as a `vmap` of
    /// the single-sequence decode graph.
    DecodeBatch,
}

#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: String,
    pub kind: GraphKind,
    pub model: String,
    pub path: PathBuf,
    /// prefill: prompt bucket P; decode: context-token bucket.
    pub seq_bucket: usize,
    /// decode only
    pub page_size: usize,
    pub n_blocks: usize,
    /// decode_batch only: batch lanes the graph steps per dispatch.
    pub batch: usize,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub n_params: usize,
    pub weights_file: PathBuf,
    pub weights_src: String,
    pub weight_names: Vec<String>,
    pub weight_shapes: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub kernel_impl: String,
    pub models: BTreeMap<String, ModelInfo>,
    pub graphs: Vec<GraphInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root.req("models")?.as_obj().context("models not an object")? {
            let get = |k: &str| -> Result<usize> {
                m.req(k)?.as_usize().with_context(|| format!("model {name}: bad {k}"))
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    vocab_size: get("vocab_size")?,
                    d_model: get("d_model")?,
                    n_layers: get("n_layers")?,
                    n_heads: get("n_heads")?,
                    n_kv_heads: get("n_kv_heads")?,
                    d_head: get("d_head")?,
                    d_ff: get("d_ff")?,
                    n_params: get("n_params")?,
                    weights_file: dir.join(
                        m.req("weights")?.as_str().context("weights not a string")?,
                    ),
                    weights_src: m
                        .req("weights_src")?
                        .as_str()
                        .unwrap_or("unknown")
                        .to_string(),
                    weight_names: m
                        .req("weight_names")?
                        .as_arr()
                        .context("weight_names")?
                        .iter()
                        .map(|v| v.as_str().unwrap_or_default().to_string())
                        .collect(),
                    weight_shapes: m
                        .req("weight_shapes")?
                        .as_arr()
                        .context("weight_shapes")?
                        .iter()
                        .map(|v| v.usize_vec())
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        let mut graphs = Vec::new();
        for g in root.req("graphs")?.as_arr().context("graphs not an array")? {
            let kind = match g.req("kind")?.as_str() {
                Some("prefill") => GraphKind::Prefill,
                Some("decode") => GraphKind::Decode,
                Some("decode_batch") => GraphKind::DecodeBatch,
                k => bail!("unknown graph kind {k:?}"),
            };
            graphs.push(GraphInfo {
                name: g.req("name")?.as_str().context("name")?.to_string(),
                kind,
                model: g.req("model")?.as_str().context("model")?.to_string(),
                path: dir.join(g.req("path")?.as_str().context("path")?),
                seq_bucket: g.req("seq_bucket")?.as_usize().context("seq_bucket")?,
                page_size: g.get("page_size").and_then(|v| v.as_usize()).unwrap_or(0),
                n_blocks: g.get("n_blocks").and_then(|v| v.as_usize()).unwrap_or(0),
                batch: g.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            });
        }

        Ok(Manifest {
            dir,
            kernel_impl: root
                .req("kernel_impl")?
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
            models,
            graphs,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest ({:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    /// Smallest prefill bucket >= `len` for a model.
    pub fn prefill_graph(&self, model: &str, len: usize) -> Result<&GraphInfo> {
        self.graphs
            .iter()
            .filter(|g| g.kind == GraphKind::Prefill && g.model == model && g.seq_bucket >= len)
            .min_by_key(|g| g.seq_bucket)
            .with_context(|| format!("no prefill bucket >= {len} for {model}"))
    }

    /// Smallest decode context bucket >= `tokens` at the given page size.
    pub fn decode_graph(&self, model: &str, page_size: usize, tokens: usize) -> Result<&GraphInfo> {
        self.graphs
            .iter()
            .filter(|g| {
                g.kind == GraphKind::Decode
                    && g.model == model
                    && g.page_size == page_size
                    && g.seq_bucket >= tokens
            })
            .min_by_key(|g| g.seq_bucket)
            .with_context(|| {
                format!("no decode bucket >= {tokens} tokens for {model} @ page {page_size}")
            })
    }

    /// Smallest batched decode graph covering `tokens` context at `batch`
    /// lanes, if the artifact set provides one (`None` = the runtime falls
    /// back to per-sequence dispatch).
    pub fn decode_batch_graph(
        &self,
        model: &str,
        page_size: usize,
        tokens: usize,
        batch: usize,
    ) -> Option<&GraphInfo> {
        self.graphs
            .iter()
            .filter(|g| {
                g.kind == GraphKind::DecodeBatch
                    && g.model == model
                    && g.page_size == page_size
                    && g.seq_bucket >= tokens
                    && g.batch >= batch
            })
            .min_by_key(|g| (g.batch, g.seq_bucket))
    }

    /// Largest decode bucket available (FullCache capacity ceiling).
    pub fn max_decode_tokens(&self, model: &str, page_size: usize) -> usize {
        self.graphs
            .iter()
            .filter(|g| {
                g.kind == GraphKind::Decode && g.model == model && g.page_size == page_size
            })
            .map(|g| g.seq_bucket)
            .max()
            .unwrap_or(0)
    }

    pub fn page_sizes(&self, model: &str) -> Vec<usize> {
        let mut ps: Vec<usize> = self
            .graphs
            .iter()
            .filter(|g| g.kind == GraphKind::Decode && g.model == model)
            .map(|g| g.page_size)
            .collect();
        ps.sort_unstable();
        ps.dedup();
        ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn manifest() -> Manifest {
        Manifest::load(artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    // The artifact-backed tests need `make artifacts` (a JAX toolchain),
    // which only exists alongside the real PJRT runtime — ignored unless
    // the `xla` feature is on.
    #[cfg_attr(not(feature = "xla"), ignore = "needs `make artifacts` (xla feature)")]
    #[test]
    fn loads_and_has_three_models() {
        let m = manifest();
        for name in ["sim-1b", "sim-3b", "sim-8b"] {
            let info = m.model(name).unwrap();
            assert!(info.n_params > 0);
            assert_eq!(info.weight_names.len(), info.weight_shapes.len());
            assert!(info.weights_file.exists());
        }
    }

    #[cfg_attr(not(feature = "xla"), ignore = "needs `make artifacts` (xla feature)")]
    #[test]
    fn bucket_selection() {
        let m = manifest();
        assert_eq!(m.prefill_graph("sim-1b", 50).unwrap().seq_bucket, 64);
        assert_eq!(m.prefill_graph("sim-1b", 64).unwrap().seq_bucket, 64);
        assert_eq!(m.prefill_graph("sim-1b", 65).unwrap().seq_bucket, 128);
        assert!(m.prefill_graph("sim-1b", 100_000).is_err());
        let d = m.decode_graph("sim-1b", 16, 200).unwrap();
        assert_eq!(d.seq_bucket, 256);
        assert_eq!(d.n_blocks, 16);
        assert!(m.max_decode_tokens("sim-1b", 16) >= 1024);
    }

    #[cfg_attr(not(feature = "xla"), ignore = "needs `make artifacts` (xla feature)")]
    #[test]
    fn page_sizes_cover_ablation() {
        let m = manifest();
        let ps = m.page_sizes("sim-1b");
        assert!(ps.contains(&8) && ps.contains(&16) && ps.contains(&32), "{ps:?}");
    }

    #[cfg_attr(not(feature = "xla"), ignore = "needs `make artifacts` (xla feature)")]
    #[test]
    fn graph_paths_exist() {
        let m = manifest();
        for g in &m.graphs {
            assert!(g.path.exists(), "missing artifact {:?}", g.path);
        }
    }
}
