//! Cache-management counters — the quantities behind the paper's
//! Limitation 1 (fragmentation), Limitation 4 (per-step eviction overhead)
//! and the Fig. 3 discussion of table-update frequency.

#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub tokens_written: u64,
    pub tokens_evicted: u64,
    /// Whole-page (structured) evictions.
    pub blocks_evicted: u64,
    pub blocks_allocated: u64,
    /// Block-table mutations (alloc, structured evict, drained page free).
    /// PagedEviction performs these only every B steps; StreamingLLM and
    /// unstructured baselines every step.
    pub table_updates: u64,
    /// Validity-mask mutations (token kills) — per-step overhead of
    /// unstructured eviction.
    pub mask_updates: u64,
    /// Bucket migrations (device buffer reallocation + copy).
    pub bucket_grows: u64,
    /// High-water mark of simultaneously allocated blocks — the sequence's
    /// real physical footprint (Fig. 3's `blocks@mid` column).
    pub peak_live_blocks: u64,
    /// High-water mark of fragmented (partially dead) pages — the paper's
    /// Limitation 1 quantity at its worst point, not just at retire time
    /// (Fig. 3's `partial@mid` column).
    pub peak_partial_blocks: u64,
    /// Times this sequence was preempted (blocks freed under memory
    /// pressure) — counts BOTH readmission paths; `swaps` is the subset
    /// that restored from a host snapshot instead of recomputing.
    pub preemptions: u64,
    /// Times this sequence was readmitted by restoring a swap-to-host
    /// snapshot (no prompt recompute, no token replay). Always
    /// `<= preemptions`; the difference is recompute readmissions.
    pub swaps: u64,
    /// Times this sequence was suspended and readmitted to recover a
    /// TRANSIENT decode error (counted separately from `preemptions`,
    /// which are memory-pressure evictions).
    pub retries: u64,
    /// Server-lifetime high-water mark of the WHOLE shared arena's
    /// allocated blocks, snapshotted when this sequence retired (folded in
    /// from `BlockManager::stats`) — the server-wide physical footprint,
    /// not a per-sequence window. A shared page counts once, so prefix
    /// caching lowers this directly.
    pub peak_arena_blocks: u64,
    /// Prompt blocks this sequence mapped from the arena's prefix index at
    /// prefill (refcount + 1 on an existing page) instead of allocating
    /// and re-materializing — the prefix-cache hit count.
    pub prefix_hit_blocks: u64,
    /// Copy-on-write page copies: a planned in-place write (token kill)
    /// found the page shared, so the writer moved to a private copy first.
    pub cow_copies: u64,
    /// Requests cancelled through the session API. Always 0 on a live
    /// per-sequence cache (a cancelled sequence never retires an output);
    /// meaningful only in server-level roll-ups — the scheduler folds
    /// each cancelled sequence's final cache counters, with this set to
    /// 1, into its `cancelled_stats` aggregate.
    pub cancelled: u64,
    /// Server-lifetime global-arena-lock acquisitions, snapshotted when
    /// this sequence retired (from `BlockManager::stats`, like
    /// `peak_arena_blocks`). Whole-server counters, so merges take the
    /// max (the latest snapshot), not the sum.
    pub arena_lock_acquisitions: u64,
    /// The subset of `arena_lock_acquisitions` that found the lock held
    /// (`try_lock` failed first) — the cross-worker contention signal.
    pub arena_contended_acquisitions: u64,
    /// Worker slot-cache refills from the global free list (lease grants).
    pub arena_cache_refills: u64,
    /// Dry-arena drains of peer slot caches — each one is an allocation
    /// that would have been a phantom OOM without the drain protocol.
    pub arena_cache_drains: u64,
}

impl CacheStats {
    pub fn merge(&mut self, o: &CacheStats) {
        self.tokens_written += o.tokens_written;
        self.tokens_evicted += o.tokens_evicted;
        self.blocks_evicted += o.blocks_evicted;
        self.blocks_allocated += o.blocks_allocated;
        self.table_updates += o.table_updates;
        self.mask_updates += o.mask_updates;
        self.bucket_grows += o.bucket_grows;
        self.peak_live_blocks = self.peak_live_blocks.max(o.peak_live_blocks);
        self.peak_partial_blocks = self.peak_partial_blocks.max(o.peak_partial_blocks);
        self.preemptions += o.preemptions;
        self.swaps += o.swaps;
        self.retries += o.retries;
        self.peak_arena_blocks = self.peak_arena_blocks.max(o.peak_arena_blocks);
        self.prefix_hit_blocks += o.prefix_hit_blocks;
        self.cow_copies += o.cow_copies;
        self.cancelled += o.cancelled;
        self.arena_lock_acquisitions =
            self.arena_lock_acquisitions.max(o.arena_lock_acquisitions);
        self.arena_contended_acquisitions =
            self.arena_contended_acquisitions.max(o.arena_contended_acquisitions);
        self.arena_cache_refills = self.arena_cache_refills.max(o.arena_cache_refills);
        self.arena_cache_drains = self.arena_cache_drains.max(o.arena_cache_drains);
    }

    /// Cache-management operations per generated token — the paper's
    /// eviction-overhead proxy.
    pub fn updates_per_token(&self) -> f64 {
        if self.tokens_written == 0 {
            return 0.0;
        }
        (self.table_updates + self.mask_updates) as f64 / self.tokens_written as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums() {
        let mut a = CacheStats { tokens_written: 2, table_updates: 1, ..Default::default() };
        let b = CacheStats { tokens_written: 3, mask_updates: 4, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.tokens_written, 5);
        assert_eq!(a.table_updates, 1);
        assert_eq!(a.mask_updates, 4);
        assert!((a.updates_per_token() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_takes_peak_maxima() {
        let mut a = CacheStats {
            peak_live_blocks: 3,
            peak_partial_blocks: 2,
            peak_arena_blocks: 10,
            preemptions: 1,
            swaps: 1,
            cancelled: 1,
            arena_lock_acquisitions: 40,
            arena_contended_acquisitions: 3,
            arena_cache_refills: 6,
            arena_cache_drains: 1,
            ..Default::default()
        };
        let b = CacheStats {
            peak_live_blocks: 7,
            peak_partial_blocks: 1,
            peak_arena_blocks: 4,
            preemptions: 2,
            swaps: 1,
            retries: 5,
            cancelled: 2,
            arena_lock_acquisitions: 55,
            arena_contended_acquisitions: 2,
            arena_cache_refills: 9,
            arena_cache_drains: 0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.peak_live_blocks, 7, "peaks merge as maxima, not sums");
        assert_eq!(a.peak_partial_blocks, 2);
        assert_eq!(a.peak_arena_blocks, 10);
        assert_eq!(a.preemptions, 3, "preemption counts are additive");
        assert_eq!(a.swaps, 2, "swap counts are additive");
        assert_eq!(a.retries, 5, "retry counts are additive");
        assert_eq!(a.cancelled, 3, "cancel counts are additive");
        assert_eq!(a.arena_lock_acquisitions, 55, "server-wide snapshots merge as maxima");
        assert_eq!(a.arena_contended_acquisitions, 3);
        assert_eq!(a.arena_cache_refills, 9);
        assert_eq!(a.arena_cache_drains, 1);
    }
}
